//! §X re-prioritization: "On the arrival of each new job, the priorities
//! of all the other jobs will be recalculated."
//!
//! Builds the [L,4] job matrix + totals from queue contents (per-user n,
//! T over all queued jobs, Q over *distinct* users) and runs it through a
//! `CostEngine` — the XLA priority kernel on the hot path, the rust
//! mirror otherwise.

use crate::util::error::Result;

use crate::cost::CostEngine;
use crate::job::{JobId, UserId};

use super::formula::{user_counts, QueueTotals};

/// The queue-resident facts about one job that the formula needs.
#[derive(Clone, Copy, Debug)]
pub struct QueuedFacts {
    pub job: JobId,
    pub user: UserId,
    pub procs: u32,
    pub quota: f32,
    pub enqueued_at: f64,
}

/// Result row of a re-prioritization sweep.
#[derive(Clone, Copy, Debug)]
pub struct Assignment {
    pub job: JobId,
    pub priority: f32,
    pub queue: usize,
}

/// Compute §X totals from the queued population.
pub fn totals(queue: &[QueuedFacts]) -> QueueTotals {
    let t_sum: f32 = queue.iter().map(|f| f.procs as f32).sum();
    // Q sums each distinct user's quota once.
    let mut seen = std::collections::BTreeMap::new();
    for f in queue {
        seen.entry(f.user.0).or_insert(f.quota);
    }
    QueueTotals {
        t_sum,
        q_sum: seen.values().sum(),
        l: queue.len(),
    }
}

/// Re-prioritize every queued job through the engine. `queue` must
/// already include any newly arrived job.
pub fn sweep(
    engine: &mut dyn CostEngine,
    queue: &[QueuedFacts],
) -> Result<Vec<Assignment>> {
    if queue.is_empty() {
        return Ok(Vec::new());
    }
    let tot = totals(queue);
    let counts = user_counts(queue.iter().map(|f| f.user.0));
    let mut rows = Vec::with_capacity(queue.len() * 4);
    for f in queue {
        rows.extend_from_slice(&[
            counts[&f.user.0] as f32,
            f.procs as f32,
            f.quota,
            f.enqueued_at as f32,
        ]);
    }
    let (pr, qidx) = engine.reprioritize(&rows, &tot.to_array())?;
    Ok(queue
        .iter()
        .zip(pr.iter().zip(qidx.iter()))
        .map(|(f, (&p, &q))| Assignment {
            job: f.job,
            priority: p,
            queue: q as usize,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::RustEngine;

    fn facts(job: u64, user: u32, procs: u32, quota: f32) -> QueuedFacts {
        QueuedFacts {
            job: JobId(job),
            user: UserId(user),
            procs,
            quota,
            enqueued_at: job as f64,
        }
    }

    #[test]
    fn totals_count_distinct_users_once() {
        let q = vec![facts(1, 1, 1, 1900.0), facts(2, 1, 5, 1900.0),
                     facts(3, 2, 1, 1700.0)];
        let t = totals(&q);
        assert_eq!(t.t_sum, 7.0);
        assert_eq!(t.q_sum, 3600.0);
        assert_eq!(t.l, 3);
    }

    #[test]
    fn fig6_sweep_through_engine() {
        let mut e = RustEngine::new();
        let q = vec![facts(1, 1, 1, 1900.0), facts(2, 1, 5, 1900.0),
                     facts(3, 2, 1, 1700.0)];
        let out = sweep(&mut e, &q).unwrap();
        assert_eq!(out.len(), 3);
        assert!((out[0].priority - 0.4586).abs() < 1e-4);
        assert!((out[1].priority + 0.6305).abs() < 1e-4);
        assert!((out[2].priority - 0.6974).abs() < 1e-4);
        assert_eq!(out[0].queue, 1);
        assert_eq!(out[1].queue, 3);
        assert_eq!(out[2].queue, 0);
    }

    #[test]
    fn empty_queue_is_noop() {
        let mut e = RustEngine::new();
        assert!(sweep(&mut e, &[]).unwrap().is_empty());
    }

    #[test]
    fn arrival_of_second_user_demotes_first() {
        // §X narrative: B's arrival reshuffles A's jobs downward.
        let mut e = RustEngine::new();
        let before = vec![facts(1, 1, 1, 1900.0), facts(2, 1, 5, 1900.0)];
        let a1_before = sweep(&mut e, &before).unwrap()[0].priority;
        let after = vec![facts(1, 1, 1, 1900.0), facts(2, 1, 5, 1900.0),
                         facts(3, 2, 1, 1700.0)];
        let a1_after = sweep(&mut e, &after).unwrap()[0].priority;
        assert!(a1_after < a1_before);
    }
}
