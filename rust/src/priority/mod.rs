//! §X priority machinery: the Pr(n) formula, aging curves (§VII/Fig 3)
//! and the whole-queue re-prioritization sweep.

pub mod aging;
pub mod formula;
pub mod reprioritize;

pub use aging::{aged_priority, aging_curve, frequency_curve};
pub use formula::{pr, queue_for_priority, threshold, QueueTotals};
pub use reprioritize::{sweep, totals, Assignment, QueuedFacts};
