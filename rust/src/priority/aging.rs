//! §VII aging: "with the passage of time, the priority of jobs in the
//! lower priority queues is increased so that it can also have a chance
//! of being executed after a certain wait time" (Fig 3's rising curve).
//!
//! The aged priority approaches 1 exponentially with waiting time:
//! `aged = pr + (1 - pr)·(1 - 2^(-wait/halflife))` — after one halflife a
//! job has closed half its gap to top priority. §X's re-prioritization
//! already militates against starvation; aging is the belt-and-braces
//! knob (disabled with halflife = 0) used when queues are long-lived.

/// Aged effective priority (used for dispatch ordering, not queue binning).
#[inline]
pub fn aged_priority(pr: f32, wait_s: f64, halflife_s: f64) -> f32 {
    if halflife_s <= 0.0 || wait_s <= 0.0 {
        return pr;
    }
    let closed = 1.0 - (-(wait_s / halflife_s) * std::f64::consts::LN_2).exp();
    pr + (1.0 - pr) * closed as f32
}

/// Fig-3 "priority vs job frequency" series: Pr(n) for n = 1..=max_n.
pub fn frequency_curve(q: f32, t: f32, cap_t: f32, cap_q: f32, max_n: usize)
    -> Vec<(usize, f32)> {
    (1..=max_n)
        .map(|n| (n, super::formula::pr(n as f32, q, t, cap_t, cap_q)))
        .collect()
}

/// Fig-3 "priority vs wait time" series for a job starting at `pr0`.
pub fn aging_curve(pr0: f32, halflife_s: f64, horizon_s: f64, steps: usize)
    -> Vec<(f64, f32)> {
    (0..=steps)
        .map(|i| {
            let t = horizon_s * i as f64 / steps as f64;
            (t, aged_priority(pr0, t, halflife_s))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_aging_at_zero_wait_or_disabled() {
        assert_eq!(aged_priority(-0.4, 0.0, 100.0), -0.4);
        assert_eq!(aged_priority(-0.4, 1e6, 0.0), -0.4);
    }

    #[test]
    fn halflife_closes_half_the_gap() {
        let aged = aged_priority(0.0, 100.0, 100.0);
        assert!((aged - 0.5).abs() < 1e-6);
        let aged2 = aged_priority(-1.0, 100.0, 100.0);
        assert!((aged2 - 0.0).abs() < 1e-6);
    }

    #[test]
    fn aging_is_monotone_and_bounded() {
        let mut last = -0.9;
        for w in [1.0, 10.0, 100.0, 1000.0, 10_000.0] {
            let a = aged_priority(-0.9, w, 300.0);
            assert!(a >= last);
            assert!(a <= 1.0);
            last = a;
        }
        assert!(aged_priority(-0.9, 1e9, 300.0) > 0.999);
    }

    #[test]
    fn fig3_frequency_curve_decreases() {
        let c = frequency_curve(1000.0, 1.0, 50.0, 5000.0, 30);
        assert_eq!(c.len(), 30);
        assert!(c.windows(2).all(|w| w[1].1 < w[0].1));
    }

    #[test]
    fn fig3_aging_curve_increases() {
        let c = aging_curve(-0.8, 600.0, 3600.0, 36);
        assert!(c.windows(2).all(|w| w[1].1 >= w[0].1));
        assert!(c.last().unwrap().1 > 0.0);
    }
}
