//! Bench: the whole event loop, end to end — DES **events/s** through
//! `run_simulation_with` across three workload shapes:
//!
//!  * `small`     — the 4-site uniform grid, gentle bulk arrivals (the
//!                  steady-state baseline every PR must at least hold);
//!  * `flood`     — a §XI-style bulk flood: big groups, fast arrivals,
//!                  deep queues (stresses the job slab, the placement
//!                  buckets and the event heap's high-water mark);
//!  * `federated` — the flood under a 4-peer federation (adds gossip,
//!                  delegation and the forward side-table).
//!
//! The `federated` shape is then re-run at `--sim-threads 2` and `4`
//! (`federated-t2` / `federated-t4`) — the conservative-PDES scaling
//! curve (events/s vs shard threads), asserted event-count-identical
//! to the serial baseline on every sample. PR 9 widens the curve:
//! `central-t2` / `central-t4` shard the plain flood (no federation)
//! by contiguous site block, and `faulted-fed-t4` runs the federated
//! flood through a site-down/up plan at 4 threads. Every parallel row
//! also reports its window stats (windows drained, mean events per
//! window) — the conservative-window efficiency the dynamic lookahead
//! is supposed to buy.
//!
//! A final `streamed-flood` shape drives the bounded-memory pipeline:
//! a diurnal arrival stream pulled lazily with spill + slot recycling
//! on, so the job slab holds *live* jobs only — it reports peak live
//! jobs (the resident bound) and process peak RSS next to events/s.
//! `streamed-flood-t2` / `streamed-flood-t4` re-run that shape through
//! the conservative PDES with per-shard spill subdirectories and the
//! k-way report merge, asserting the event count matches the serial
//! streamed baseline and that peak live jobs stays below the submitted
//! total on every sample.
//!
//! Besides events/s it reports each shape's **peak live jobs** (slab
//! high-water mark) and **peak heap depth** (pending events) — the two
//! sizes that bound the event loop's memory footprint.
//!
//! `--json <path>` serializes the results; ci.sh writes them to
//! `BENCH_world.json`, the perf-trajectory data point future PRs
//! soft-compare against (⚠ at >15% events/s regression). Smoke mode
//! (`--smoke` / `DIANA_BENCH_SMOKE=1`): fewer samples and jobs, same
//! output shape.

mod common;
use common::{bench, black_box};

use diana::config::{presets, ArrivalKind, GridConfig, SourceMode};
use diana::coordinator::{generate_workload, run_simulation,
                         run_simulation_with};
use diana::coordinator::run_simulation_with_faults;
use diana::scenario::{FaultEvent, FaultKind, FaultPlan};
use diana::sim::{try_run_parallel, PdesOutcome};

struct ShapeResult {
    name: &'static str,
    events_per_s: f64,
    events: u64,
    peak_live_jobs: usize,
    peak_heap_depth: usize,
    /// Conservative windows drained (0 on serial rows).
    windows: u64,
    /// Shard events processed inside those windows.
    window_events: u64,
}

fn small_cfg(smoke: bool) -> GridConfig {
    let mut cfg = presets::uniform_grid(4, 4);
    cfg.workload.jobs = if smoke { 60 } else { 300 };
    cfg.workload.bulk_size = 10;
    cfg.workload.cpu_sec_median = 60.0;
    cfg.workload.cpu_sec_sigma = 0.3;
    cfg.workload.in_mb_median = 50.0;
    cfg.seed = 11;
    cfg
}

fn flood_cfg(smoke: bool) -> GridConfig {
    let mut cfg = presets::uniform_grid(8, 16);
    cfg.workload.jobs = if smoke { 200 } else { 2000 };
    cfg.workload.bulk_size = 50;
    cfg.workload.arrival_rate = 5.0;
    cfg.workload.cpu_sec_median = 120.0;
    cfg.workload.cpu_sec_sigma = 0.4;
    cfg.workload.in_mb_median = 100.0;
    cfg.seed = 12;
    cfg
}

fn federated_cfg(smoke: bool) -> GridConfig {
    let mut cfg = flood_cfg(smoke);
    cfg.workload.jobs = if smoke { 160 } else { 1600 };
    cfg.federation.peers = 4;
    cfg.federation.gossip_period_s = 60.0;
    cfg.seed = 13;
    cfg
}

/// The bounded-memory shape: a diurnal arrival stream (≈0.86 jobs/s
/// effective vs ≈2 jobs/s of service capacity, so queues stay shallow)
/// pulled lazily through the streamed path with spill + slot recycling.
fn streamed_cfg(smoke: bool) -> GridConfig {
    let mut cfg = presets::uniform_grid(8, 16);
    cfg.workload.jobs = if smoke { 300 } else { 10_000 };
    cfg.workload.bulk_size = 25;
    cfg.workload.source = SourceMode::Arrival;
    cfg.workload.arrival = ArrivalKind::Diurnal;
    cfg.workload.arrival_rate = 0.06;
    cfg.workload.cpu_sec_median = 60.0;
    cfg.workload.cpu_sec_sigma = 0.3;
    cfg.workload.in_mb_median = 50.0;
    cfg.seed = 14;
    cfg
}

/// Peak resident set (kB) from /proc/self/status, if readable (Linux).
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn write_json(path: &str, smoke: bool, shapes: &[ShapeResult]) {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"bench_world\",\n");
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str("  \"shapes\": [\n");
    for (i, s) in shapes.iter().enumerate() {
        let mean_per_window = if s.windows > 0 {
            s.window_events as f64 / s.windows as f64
        } else {
            0.0
        };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"events_per_s\": {:.1}, \
             \"events\": {}, \"peak_live_jobs\": {}, \
             \"peak_heap_depth\": {}, \"windows\": {}, \
             \"mean_events_per_window\": {:.1}}}{}\n",
            s.name,
            s.events_per_s,
            s.events,
            s.peak_live_jobs,
            s.peak_heap_depth,
            s.windows,
            mean_per_window,
            if i + 1 < shapes.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n");
    match peak_rss_kb() {
        Some(kb) => out.push_str(&format!("  \"peak_rss_kb\": {kb}\n")),
        None => out.push_str("  \"peak_rss_kb\": null\n"),
    }
    out.push_str("}\n");
    if let Err(e) = std::fs::write(path, out) {
        eprintln!("bench_world: could not write {path}: {e}");
        std::process::exit(1);
    }
    println!("bench_world: wrote {path}");
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || std::env::var("DIANA_BENCH_SMOKE")
            .map_or(false, |v| !v.is_empty() && v != "0");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let (warmup, samples) = if smoke { (1, 2) } else { (2, 10) };
    println!("== bench_world: end-to-end DES events/s {}==",
             if smoke { "(smoke) " } else { "" });

    let shapes: [(&'static str, GridConfig); 3] = [
        ("small", small_cfg(smoke)),
        ("flood", flood_cfg(smoke)),
        ("federated", federated_cfg(smoke)),
    ];
    let mut results = Vec::new();
    for (name, cfg) in shapes {
        let subs = generate_workload(&cfg);
        let mut events = 0u64;
        let mut peak_live = 0usize;
        let mut peak_heap = 0usize;
        let r = bench(
            &format!("world {name:<9} jobs={}", cfg.workload.jobs),
            warmup,
            samples,
            || {
                let (w, report) =
                    run_simulation_with(&cfg, subs.clone()).unwrap();
                assert_eq!(report.jobs, cfg.workload.jobs, "{name}: dropped jobs");
                events = w.events_processed();
                peak_live = w.peak_live_jobs();
                peak_heap = w.peak_heap_depth();
                black_box(&w);
            },
        );
        r.throughput(events as f64, "events");
        let events_per_s = events as f64 / (r.mean_ns() / 1e9);
        println!(
            "  └ peak live jobs {peak_live}, peak heap depth {peak_heap}, \
             {events} events/run"
        );
        println!("world events/s ({name}): {events_per_s:.0}");
        results.push(ShapeResult {
            name,
            events_per_s,
            events,
            peak_live_jobs: peak_live,
            peak_heap_depth: peak_heap,
            windows: 0,
            window_events: 0,
        });
    }
    // PDES scaling shape: the federated workload again, sharded one
    // EventQueue+JobStore per peer on 2 and 4 threads (`--sim-threads`).
    // The serial `federated` entry above is the threads=1 baseline, so
    // the three rows together are the events/s-vs-threads curve that
    // lands in BENCH_world.json. Each sample must process exactly the
    // serial event count — anything else means the conservative windows
    // leaked and the numbers would be fiction.
    let serial_events = results
        .iter()
        .find(|r| r.name == "federated")
        .map(|r| r.events)
        .unwrap();
    {
        // Guard against a silently-declined (and therefore serial, and
        // therefore flat) scaling curve.
        let mut probe = federated_cfg(smoke);
        probe.sim.threads = 2;
        let subs = generate_workload(&probe);
        match try_run_parallel(&probe, subs, &FaultPlan::default()).unwrap() {
            PdesOutcome::Done(..) => {}
            PdesOutcome::Declined { reason, .. } => {
                panic!("federated bench shape declined the PDES path: {reason}")
            }
        }
    }
    for (name, threads) in [("federated-t2", 2usize), ("federated-t4", 4)] {
        let mut cfg = federated_cfg(smoke);
        cfg.sim.threads = threads;
        let subs = generate_workload(&cfg);
        let mut events = 0u64;
        let mut windows = 0u64;
        let mut window_events = 0u64;
        let r = bench(
            &format!("world {name:<9} jobs={}", cfg.workload.jobs),
            warmup,
            samples,
            || {
                let (w, report) =
                    run_simulation_with(&cfg, subs.clone()).unwrap();
                assert_eq!(report.jobs, cfg.workload.jobs, "{name}: dropped jobs");
                assert_eq!(
                    report.events, serial_events,
                    "{name}: event count diverged from the serial baseline"
                );
                assert!(report.pdes_parallel, "{name}: fell back to serial");
                // Merged across shards by the PDES assembly (the world's
                // own counter only covers shard 0 here).
                events = report.events;
                windows = report.pdes_windows;
                window_events = report.pdes_window_events;
                black_box(&w);
            },
        );
        r.throughput(events as f64, "events");
        let events_per_s = events as f64 / (r.mean_ns() / 1e9);
        println!(
            "  └ {windows} windows, {:.1} shard events/window",
            if windows > 0 {
                window_events as f64 / windows as f64
            } else {
                0.0
            }
        );
        println!("world events/s ({name}): {events_per_s:.0}");
        results.push(ShapeResult {
            name,
            events_per_s,
            events,
            // Per-shard peaks are not comparable to the single-queue
            // serial shapes; report the scaling rows as curve-only.
            peak_live_jobs: 0,
            peak_heap_depth: 0,
            windows,
            window_events,
        });
    }
    // Central scaling shape (PR 9): the plain flood — no federation at
    // all — sharded by contiguous site block on 2 and 4 threads, with
    // the single DIANA scheduler's placement rounds replayed at window
    // barriers on every replica. The serial `flood` row above is the
    // threads=1 baseline of this curve.
    let flood_events = results
        .iter()
        .find(|r| r.name == "flood")
        .map(|r| r.events)
        .unwrap();
    {
        let mut probe = flood_cfg(smoke);
        probe.sim.threads = 2;
        let subs = generate_workload(&probe);
        match try_run_parallel(&probe, subs, &FaultPlan::default()).unwrap() {
            PdesOutcome::Done(..) => {}
            PdesOutcome::Declined { reason, .. } => {
                panic!("central bench shape declined the PDES path: {reason}")
            }
        }
    }
    for (name, threads) in [("central-t2", 2usize), ("central-t4", 4)] {
        let mut cfg = flood_cfg(smoke);
        cfg.sim.threads = threads;
        let subs = generate_workload(&cfg);
        let mut events = 0u64;
        let mut windows = 0u64;
        let mut window_events = 0u64;
        let r = bench(
            &format!("world {name:<9} jobs={}", cfg.workload.jobs),
            warmup,
            samples,
            || {
                let (w, report) =
                    run_simulation_with(&cfg, subs.clone()).unwrap();
                assert_eq!(report.jobs, cfg.workload.jobs, "{name}: dropped jobs");
                assert_eq!(
                    report.events, flood_events,
                    "{name}: event count diverged from the serial baseline"
                );
                assert!(report.pdes_parallel, "{name}: fell back to serial");
                events = report.events;
                windows = report.pdes_windows;
                window_events = report.pdes_window_events;
                black_box(&w);
            },
        );
        r.throughput(events as f64, "events");
        let events_per_s = events as f64 / (r.mean_ns() / 1e9);
        println!(
            "  └ {windows} windows, {:.1} shard events/window",
            if windows > 0 {
                window_events as f64 / windows as f64
            } else {
                0.0
            }
        );
        println!("world events/s ({name}): {events_per_s:.0}");
        results.push(ShapeResult {
            name,
            events_per_s,
            events,
            peak_live_jobs: 0,
            peak_heap_depth: 0,
            windows,
            window_events,
        });
    }
    // Faulted federated scaling shape (PR 9): the federated flood
    // through a site-lifecycle plan — s2 dies mid-flood with queued work
    // and recovers later — at 4 threads. Site liveness is a replicated
    // event, so the parallel run must process exactly the event count of
    // its own serial faulted baseline (computed once below; the clean
    // `federated` row is NOT the baseline here — faults change the
    // stream).
    {
        let plan = FaultPlan {
            events: vec![
                FaultEvent {
                    at: 60.0,
                    kind: FaultKind::SiteDown { site: "s2".into() },
                },
                FaultEvent {
                    at: 360.0,
                    kind: FaultKind::SiteUp { site: "s2".into() },
                },
            ],
        };
        let base_cfg = federated_cfg(smoke);
        let base_subs = generate_workload(&base_cfg);
        let (bw, _) =
            run_simulation_with_faults(&base_cfg, base_subs, &plan).unwrap();
        let faulted_serial_events = bw.events_processed();
        let mut cfg = federated_cfg(smoke);
        cfg.sim.threads = 4;
        let subs = generate_workload(&cfg);
        let mut events = 0u64;
        let mut windows = 0u64;
        let mut window_events = 0u64;
        let r = bench(
            &format!("world faulted-fed-t4 jobs={}", cfg.workload.jobs),
            warmup,
            samples,
            || {
                let (w, report) =
                    run_simulation_with_faults(&cfg, subs.clone(), &plan)
                        .unwrap();
                assert_eq!(
                    report.jobs, cfg.workload.jobs,
                    "faulted-fed-t4: dropped jobs"
                );
                assert_eq!(
                    report.events, faulted_serial_events,
                    "faulted-fed-t4: event count diverged from the serial \
                     faulted baseline"
                );
                assert!(
                    report.pdes_parallel,
                    "faulted-fed-t4: fell back to serial"
                );
                events = report.events;
                windows = report.pdes_windows;
                window_events = report.pdes_window_events;
                black_box(&w);
            },
        );
        r.throughput(events as f64, "events");
        let events_per_s = events as f64 / (r.mean_ns() / 1e9);
        println!(
            "  └ {windows} windows, {:.1} shard events/window",
            if windows > 0 {
                window_events as f64 / windows as f64
            } else {
                0.0
            }
        );
        println!("world events/s (faulted-fed-t4): {events_per_s:.0}");
        results.push(ShapeResult {
            name: "faulted-fed-t4",
            events_per_s,
            events,
            peak_live_jobs: 0,
            peak_heap_depth: 0,
            windows,
            window_events,
        });
    }
    // Streamed-flood: the bounded-memory shape. The workload is pulled
    // lazily (no materialized submission list), completed records spill
    // to sorted shards and the job slab recycles — peak live jobs is
    // the resident bound the run actually paid for, and it must sit far
    // below the total job count or the streaming pipeline regressed.
    {
        let mut cfg = streamed_cfg(smoke);
        let spill = std::env::temp_dir().join("diana-bench-streamed-spill");
        cfg.sim.spill_dir = spill.to_string_lossy().into_owned();
        let mut events = 0u64;
        let mut peak_live = 0usize;
        let mut peak_heap = 0usize;
        let mut submitted = 0usize;
        let r = bench(
            &format!("world streamed-flood jobs={}", cfg.workload.jobs),
            warmup,
            samples,
            || {
                let (w, report) = run_simulation(&cfg).unwrap();
                assert_eq!(
                    report.jobs, cfg.workload.jobs,
                    "streamed-flood: dropped jobs"
                );
                events = w.events_processed();
                peak_live = w.peak_live_jobs();
                peak_heap = w.peak_heap_depth();
                submitted = w.submitted_jobs();
                black_box(&w);
            },
        );
        r.throughput(events as f64, "events");
        let events_per_s = events as f64 / (r.mean_ns() / 1e9);
        assert!(
            peak_live < submitted,
            "streamed-flood: slab never recycled \
             (peak live {peak_live} of {submitted})"
        );
        println!(
            "  └ peak live jobs {peak_live} of {submitted} submitted \
             (slab recycled), peak heap depth {peak_heap}"
        );
        if let Some(kb) = peak_rss_kb() {
            println!(
                "  └ process peak RSS {:.1} MB (high-water across all \
                 shapes)",
                kb as f64 / 1024.0
            );
        }
        println!("world events/s (streamed-flood): {events_per_s:.0}");
        results.push(ShapeResult {
            name: "streamed-flood",
            events_per_s,
            events,
            peak_live_jobs: peak_live,
            peak_heap_depth: peak_heap,
            windows: 0,
            window_events: 0,
        });
        std::fs::remove_dir_all(&spill).ok();
    }
    // Streamed-flood under the PDES (the sharded-spill shape): the same
    // lazy diurnal stream at `--sim-threads 2` and `4`, each shard
    // sealing into its own `shard-<p>/` spill subdirectory and the
    // report k-way merged back together. Every sample must process
    // exactly the serial streamed event count, actually take the
    // parallel path, and keep peak live jobs below the submitted total
    // — the per-shard recycling claim, measured.
    let streamed_events = results
        .iter()
        .find(|r| r.name == "streamed-flood")
        .map(|r| r.events)
        .unwrap();
    for (name, threads) in
        [("streamed-flood-t2", 2usize), ("streamed-flood-t4", 4)]
    {
        let mut cfg = streamed_cfg(smoke);
        cfg.sim.threads = threads;
        let spill = std::env::temp_dir()
            .join(format!("diana-bench-streamed-spill-t{threads}"));
        cfg.sim.spill_dir = spill.to_string_lossy().into_owned();
        let mut events = 0u64;
        let mut windows = 0u64;
        let mut window_events = 0u64;
        let mut peak_live = 0usize;
        let mut submitted = 0usize;
        let r = bench(
            &format!("world {name} jobs={}", cfg.workload.jobs),
            warmup,
            samples,
            || {
                let (w, report) = run_simulation(&cfg).unwrap();
                assert_eq!(
                    report.jobs, cfg.workload.jobs,
                    "{name}: dropped jobs"
                );
                assert_eq!(
                    report.events, streamed_events,
                    "{name}: event count diverged from the serial \
                     streamed baseline"
                );
                assert!(report.pdes_parallel, "{name}: fell back to serial");
                events = report.events;
                windows = report.pdes_windows;
                window_events = report.pdes_window_events;
                peak_live = w.peak_live_jobs();
                submitted = w.submitted_jobs();
                black_box(&w);
            },
        );
        r.throughput(events as f64, "events");
        let events_per_s = events as f64 / (r.mean_ns() / 1e9);
        assert!(
            peak_live < submitted,
            "{name}: slab never recycled \
             (peak live {peak_live} of {submitted})"
        );
        println!(
            "  └ {windows} windows, {:.1} shard events/window, peak \
             live jobs {peak_live} of {submitted} submitted",
            if windows > 0 {
                window_events as f64 / windows as f64
            } else {
                0.0
            }
        );
        println!("world events/s ({name}): {events_per_s:.0}");
        results.push(ShapeResult {
            name,
            events_per_s,
            events,
            peak_live_jobs: peak_live,
            // Heap depth is per-shard here, not comparable to the
            // single-queue serial rows.
            peak_heap_depth: 0,
            windows,
            window_events,
        });
        std::fs::remove_dir_all(&spill).ok();
    }
    if let Some(path) = json_path {
        write_json(&path, smoke, &results);
    }
}
