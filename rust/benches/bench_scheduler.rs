//! Bench: end-to-end matchmaking throughput of the L3 coordinator —
//! picker.pick() for a full bulk batch, per policy (jobs scheduled per
//! second, the §XI "frequency of potentially millions of jobs" claim).

mod common;
use common::{bench, black_box};

use diana::config::{presets, Policy, SchedulerConfig};
use diana::cost::RustEngine;
use diana::data::Catalog;
use diana::job::{Job, JobClass, JobId, UserId};
use diana::network::{PingerMonitor, Topology};
use diana::scheduler::{make_picker, GridView, SiteSnapshot};
use diana::util::Pcg64;

fn main() {
    println!("== bench_scheduler: matchmaking rounds per policy ==");
    let cfg = presets::uniform_grid(16, 32);
    let topo = Topology::from_config(&cfg);
    let monitor = PingerMonitor::new(&topo, 0.0, 1);
    let mut rng = Pcg64::new(3);
    let mut catalog = Catalog::new();
    for d in 0..50 {
        catalog.add(&format!("d{d}"), rng.uniform(100.0, 30_000.0),
                    vec![rng.below(16) as usize]);
    }
    let sites: Vec<SiteSnapshot> = (0..16)
        .map(|_| SiteSnapshot {
            queue_len: rng.below(100) as usize,
            capability: 32.0,
            load: rng.next_f64(),
            free_slots: rng.below(33) as usize,
            cpus: 32,
            alive: true,
        })
        .collect();
    let jobs: Vec<Job> = (0..256)
        .map(|i| Job {
            id: JobId(i),
            user: UserId((i % 10) as u32),
            group: None,
            class: match i % 3 {
                0 => JobClass::ComputeIntensive,
                1 => JobClass::DataIntensive,
                _ => JobClass::Both,
            },
            input: Some(rng.below(50) as usize),
            in_mb: rng.uniform(10.0, 10_000.0),
            out_mb: 50.0,
            exe_mb: 20.0,
            cpu_sec: rng.uniform(60.0, 3600.0),
            procs: 1 + (i % 4) as usize,
            submit_site: 0,
            submit_time: 0.0,
            quota: 1000.0,
            migrations: 0,
        })
        .collect();
    let view = GridView {
        now: 0.0,
        sites: &sites,
        monitor: &monitor,
        catalog: &catalog,
        q_total: 500,
        epoch: 0,
    };

    for policy in [Policy::Diana, Policy::FcfsBroker, Policy::Greedy,
                   Policy::DataLocal, Policy::Random] {
        let mut picker = make_picker(policy, Box::new(RustEngine::new()),
                                     &SchedulerConfig::default(), 1);
        let r = bench(&format!("{:<11} pick 256 jobs x 16 sites",
                               policy.name()), 10, 200, || {
            black_box(picker.pick(&jobs, &view).unwrap());
        });
        r.throughput(256.0, "jobs");
    }
}
