//! Bench: §X whole-queue re-prioritization (runs on EVERY arrival) —
//! rust mirror vs the AOT priority kernel, across queue lengths.

mod common;
use common::{bench, black_box};

use diana::cost::{CostEngine, RustEngine};
use diana::util::Pcg64;

fn queue(rng: &mut Pcg64, l: usize) -> (Vec<f32>, [f32; 4]) {
    let mut jobs = Vec::with_capacity(l * 4);
    for _ in 0..l {
        jobs.extend_from_slice(&[
            1.0 + rng.below(50) as f32,
            1.0 + rng.below(32) as f32,
            rng.uniform(100.0, 5000.0) as f32,
            0.0,
        ]);
    }
    let totals = [rng.uniform(50.0, 500.0) as f32,
                  rng.uniform(1000.0, 50_000.0) as f32, l as f32, 0.0];
    (jobs, totals)
}

fn main() {
    println!("== bench_priority: §X re-prioritization sweep ==");
    let mut rng = Pcg64::new(2);
    for l in [16usize, 128, 512, 4096] {
        let (jobs, totals) = queue(&mut rng, l);
        let mut rust = RustEngine::new();
        let r = bench(&format!("rust  reprioritize L={l}"), 20, 200, || {
            black_box(rust.reprioritize(&jobs, &totals).unwrap());
        });
        r.throughput(l as f64, "jobs");
    }
    if cfg!(feature = "xla") && diana::runtime::artifacts_available() {
        let mut xla = diana::runtime::XlaEngine::load_default().unwrap();
        for l in [16usize, 512, 4096] {
            let (jobs, totals) = queue(&mut rng, l);
            let r = bench(&format!("xla   reprioritize L={l}"), 5, 50, || {
                black_box(xla.reprioritize(&jobs, &totals).unwrap());
            });
            r.throughput(l as f64, "jobs");
        }
    } else {
        println!("(xla feature off or artifacts missing — xla engine skipped)");
    }
}
