//! Bench: sweep-runner scaling — the same smoke matrix at increasing
//! worker counts. The interesting number is runs/s levelling off once
//! workers exceed the matrix width.

mod common;
use common::{bench, black_box};

use diana::scenario::{library, run_sweep};

fn main() {
    println!("== bench_sweep: scenario sweep runner ==");
    let spec = library::load("smoke").unwrap();
    let n_runs = spec.expand().unwrap().len();
    let mut baseline_ns = 0.0;
    for j in [1usize, 2, 4, 8] {
        let r = bench(&format!("smoke sweep ({n_runs} runs) -j {j}"), 1, 8,
                      || {
            let rep = run_sweep(&spec, j).unwrap();
            black_box(rep.runs.len());
        });
        r.throughput(n_runs as f64, "runs");
        if j == 1 {
            baseline_ns = r.mean_ns();
        } else {
            println!("  └ speedup over -j 1: {:.2}x",
                     baseline_ns / r.mean_ns());
        }
    }

    // Spec expansion alone (pure config cloning, no simulation).
    let flash = library::load("flash-crowd").unwrap();
    let r = bench("flash-crowd expand (8-run matrix)", 3, 30, || {
        black_box(flash.expand().unwrap().len());
    });
    r.throughput(8.0, "runs");
}
