//! Bench: the §V matchmaking core, old-style vs workspace path.
//!
//! Measures rounds/s of the full J×S evaluation (input build + kernel +
//! argmins) at three shapes, comparing:
//!
//!  * `old-style` — what every round did before the incremental
//!    refactor: fresh `CostInputs` + fresh `ScheduleOut` + per-pair
//!    monitor observation, ~10 allocations per round;
//!  * `workspace` — `build_cost_inputs_into` + `schedule_step_into`
//!    through a reused `CostWorkspace` with an epoch-stable
//!    `ReplicaCache`: zero steady-state allocation.
//!
//! The closing `matchmaker events/s` line (jobs matched per second on
//! the workspace path at the largest shape) is the throughput counter
//! ci.sh smoke-greps and BENCH trajectories track; the sweep runner
//! surfaces the same counter per matrix point in its aggregate table.
//!
//! Smoke mode (`--smoke` argument or `DIANA_BENCH_SMOKE=1`): tiny
//! sample counts, same output shape — used by ci.sh.

mod common;
use common::{bench, black_box};

use diana::config::presets;
use diana::cost::{CostWorkspace, RustEngine, CostEngine, Weights};
use diana::data::{Catalog, ReplicaCache};
use diana::job::{Job, JobClass, JobId, UserId};
use diana::network::{PingerMonitor, Topology};
use diana::scheduler::{build_cost_inputs, build_cost_inputs_into, GridView,
                       SiteSnapshot};
use diana::util::Pcg64;

struct Fixture {
    monitor: PingerMonitor,
    catalog: Catalog,
    sites: Vec<SiteSnapshot>,
    jobs: Vec<Job>,
}

fn fixture(n_jobs: usize, n_sites: usize) -> Fixture {
    let cfg = presets::uniform_grid(n_sites, 32);
    let topo = Topology::from_config(&cfg);
    let monitor = PingerMonitor::new(&topo, 0.0, 1);
    let mut rng = Pcg64::new(0x5eed ^ (n_jobs as u64) ^ ((n_sites as u64) << 20));
    let mut catalog = Catalog::new();
    let n_ds = 32.min(n_sites * 2);
    for d in 0..n_ds {
        catalog.add(&format!("d{d}"), rng.uniform(100.0, 30_000.0),
                    vec![rng.below(n_sites as u64) as usize]);
    }
    let sites = (0..n_sites)
        .map(|_| SiteSnapshot {
            queue_len: rng.below(100) as usize,
            capability: 32.0,
            load: rng.next_f64(),
            free_slots: rng.below(33) as usize,
            cpus: 32,
            alive: true,
        })
        .collect();
    let jobs = (0..n_jobs as u64)
        .map(|i| Job {
            id: JobId(i),
            user: UserId((i % 10) as u32),
            group: None,
            class: match i % 3 {
                0 => JobClass::ComputeIntensive,
                1 => JobClass::DataIntensive,
                _ => JobClass::Both,
            },
            input: if i % 4 == 3 {
                None
            } else {
                Some(rng.below(n_ds as u64) as usize)
            },
            in_mb: rng.uniform(10.0, 10_000.0),
            out_mb: 50.0,
            exe_mb: 20.0,
            cpu_sec: rng.uniform(60.0, 3600.0),
            procs: 1,
            submit_site: 0,
            submit_time: 0.0,
            quota: 1000.0,
            migrations: 0,
        })
        .collect();
    Fixture { monitor, catalog, sites, jobs }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("DIANA_BENCH_SMOKE")
            .map_or(false, |v| !v.is_empty() && v != "0");
    let (warmup, samples) = if smoke { (1, 3) } else { (20, 200) };
    println!("== bench_matchmaker: §V cost rounds, old-style vs workspace \
              {}==", if smoke { "(smoke) " } else { "" });

    let mut closing_events_per_s = 0.0;
    for (nj, ns) in [(1usize, 10usize), (32, 50), (256, 200)] {
        let f = fixture(nj, ns);
        let view = GridView {
            now: 0.0,
            sites: &f.sites,
            monitor: &f.monitor,
            catalog: &f.catalog,
            q_total: 500,
            epoch: 0,
        };
        let w = Weights { q_total: 500.0, ..Weights::default() };

        let mut engine = RustEngine::new();
        let r_old = bench(
            &format!("old-style  J={nj:<3} S={ns:<3} (alloc per round)"),
            warmup, samples, || {
                let inp = build_cost_inputs(&f.jobs, &view);
                black_box(engine.schedule_step(&inp, &w).unwrap());
            });
        r_old.throughput(nj as f64, "jobs");

        let mut ws = CostWorkspace::new();
        let mut replicas = ReplicaCache::new();
        let r_new = bench(
            &format!("workspace  J={nj:<3} S={ns:<3} (reused buffers)"),
            warmup, samples, || {
                build_cost_inputs_into(&f.jobs, &view, &mut ws.inputs,
                                       &mut replicas);
                engine
                    .schedule_step_into(&ws.inputs, &w, &mut ws.out)
                    .unwrap();
                black_box(ws.out.best_total[0]);
            });
        r_new.throughput(nj as f64, "jobs");
        println!("  └ workspace speedup: {:.2}x",
                 r_old.mean_ns() / r_new.mean_ns());

        // Sanity: both paths agree on every argmin.
        let inp = build_cost_inputs(&f.jobs, &view);
        let old = engine.schedule_step(&inp, &w).unwrap();
        assert_eq!(old.best_total, ws.out.best_total);
        assert_eq!(old.best_compute, ws.out.best_compute);
        assert_eq!(old.best_data, ws.out.best_data);

        closing_events_per_s = nj as f64 / (r_new.mean_ns() / 1e9);
    }
    println!("matchmaker events/s (J=256 S=200, workspace): {:.0}",
             closing_events_per_s);
}
