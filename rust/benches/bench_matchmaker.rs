//! Bench: the §V matchmaking core — old-style vs scalar-workspace vs the
//! SoA-vectorized kernel.
//!
//! Measures rounds/s of the full J×S evaluation (input build + kernel +
//! argmins) at four shapes, comparing:
//!
//!  * `old-style` — what every round did before the incremental
//!    refactor: fresh `CostInputs` + fresh `ScheduleOut` + per-pair
//!    monitor observation, ~10 allocations per round (runs the scalar
//!    oracle);
//!  * `scalar` — `build_cost_inputs_into` + `schedule_step_scalar_into`
//!    through a reused `CostWorkspace`: zero steady-state allocation,
//!    pre-SIMD arithmetic — the PR-4 baseline the SoA rows are measured
//!    against;
//!  * `soa` — same workspace path through the vectorized
//!    `schedule_step_into`: hoisted per-site columns + chunked
//!    branch-free column sweep + separate argmin pass.
//!
//! Every shape cross-checks the three paths: argmins `==` across all
//! three, and scalar vs SoA `to_bits`-identical on the float matrices
//! (the kernel_differential.rs contract, re-asserted on bench inputs).
//!
//! The closing `matchmaker events/s` line (jobs matched per second on
//! the SoA path at the largest shape) is the throughput counter ci.sh
//! smoke-greps; `--json <path>` serializes per-shape rows, which ci.sh
//! snapshots into BENCH_matchmaker.json alongside BENCH_world.json and
//! soft-warns on >15% regressions.
//!
//! Smoke mode (`--smoke` argument or `DIANA_BENCH_SMOKE=1`): tiny
//! sample counts, same output shape — used by ci.sh.

mod common;
use common::{bench, black_box};

use diana::config::presets;
use diana::cost::{schedule_step_scalar_into, CostEngine, CostWorkspace,
                  RustEngine, Weights};
use diana::data::{Catalog, ReplicaCache};
use diana::job::{Job, JobClass, JobId, UserId};
use diana::network::{PingerMonitor, Topology};
use diana::scheduler::{build_cost_inputs, build_cost_inputs_into, GridView,
                       SiteSnapshot};
use diana::util::Pcg64;

struct Fixture {
    monitor: PingerMonitor,
    catalog: Catalog,
    sites: Vec<SiteSnapshot>,
    jobs: Vec<Job>,
}

fn fixture(n_jobs: usize, n_sites: usize) -> Fixture {
    let cfg = presets::uniform_grid(n_sites, 32);
    let topo = Topology::from_config(&cfg);
    let monitor = PingerMonitor::new(&topo, 0.0, 1);
    let mut rng = Pcg64::new(0x5eed ^ (n_jobs as u64) ^ ((n_sites as u64) << 20));
    let mut catalog = Catalog::new();
    let n_ds = 32.min(n_sites * 2);
    for d in 0..n_ds {
        catalog.add(&format!("d{d}"), rng.uniform(100.0, 30_000.0),
                    vec![rng.below(n_sites as u64) as usize]);
    }
    let sites = (0..n_sites)
        .map(|_| SiteSnapshot {
            queue_len: rng.below(100) as usize,
            capability: 32.0,
            load: rng.next_f64(),
            free_slots: rng.below(33) as usize,
            cpus: 32,
            alive: true,
        })
        .collect();
    let jobs = (0..n_jobs as u64)
        .map(|i| Job {
            id: JobId(i),
            user: UserId((i % 10) as u32),
            group: None,
            class: match i % 3 {
                0 => JobClass::ComputeIntensive,
                1 => JobClass::DataIntensive,
                _ => JobClass::Both,
            },
            input: if i % 4 == 3 {
                None
            } else {
                Some(rng.below(n_ds as u64) as usize)
            },
            in_mb: rng.uniform(10.0, 10_000.0),
            out_mb: 50.0,
            exe_mb: 20.0,
            cpu_sec: rng.uniform(60.0, 3600.0),
            procs: 1,
            submit_site: 0,
            submit_time: 0.0,
            quota: 1000.0,
            migrations: 0,
        })
        .collect();
    Fixture { monitor, catalog, sites, jobs }
}

struct ShapeResult {
    nj: usize,
    ns: usize,
    old_rounds_per_s: f64,
    scalar_rounds_per_s: f64,
    soa_rounds_per_s: f64,
    soa_speedup_vs_scalar: f64,
}

fn write_json(path: &str, smoke: bool, shapes: &[ShapeResult]) {
    let mut out = String::new();
    out.push_str("{\n  \"bench\": \"bench_matchmaker\",\n");
    out.push_str(&format!("  \"smoke\": {smoke},\n"));
    out.push_str("  \"shapes\": [\n");
    for (i, s) in shapes.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"J{}xS{}\", \"old_rounds_per_s\": {:.1}, \
             \"scalar_rounds_per_s\": {:.1}, \"soa_rounds_per_s\": {:.1}, \
             \"soa_speedup_vs_scalar\": {:.3}}}{}\n",
            s.nj,
            s.ns,
            s.old_rounds_per_s,
            s.scalar_rounds_per_s,
            s.soa_rounds_per_s,
            s.soa_speedup_vs_scalar,
            if i + 1 < shapes.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(path, out) {
        eprintln!("bench_matchmaker: could not write {path}: {e}");
        std::process::exit(1);
    }
    println!("bench_matchmaker: wrote {path}");
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || std::env::var("DIANA_BENCH_SMOKE")
            .map_or(false, |v| !v.is_empty() && v != "0");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let (warmup, samples) = if smoke { (1, 3) } else { (20, 200) };
    println!("== bench_matchmaker: §V cost rounds, old-style vs scalar vs \
              SoA {}==", if smoke { "(smoke) " } else { "" });

    let mut results = Vec::new();
    let mut closing_events_per_s = 0.0;
    for (nj, ns) in [(1usize, 10usize), (32, 50), (256, 200), (1024, 500)] {
        let f = fixture(nj, ns);
        let view = GridView {
            now: 0.0,
            sites: &f.sites,
            monitor: &f.monitor,
            catalog: &f.catalog,
            q_total: 500,
            epoch: 0,
        };
        let w = Weights { q_total: 500.0, ..Weights::default() };

        let mut engine = RustEngine::new();
        let r_old = bench(
            &format!("old-style  J={nj:<4} S={ns:<3} (alloc, scalar oracle)"),
            warmup, samples, || {
                let inp = build_cost_inputs(&f.jobs, &view);
                black_box(engine.schedule_step(&inp, &w).unwrap());
            });
        r_old.throughput(nj as f64, "jobs");

        let mut scalar_ws = CostWorkspace::new();
        let mut replicas = ReplicaCache::new();
        let r_scalar = bench(
            &format!("scalar     J={nj:<4} S={ns:<3} (workspace, pre-SIMD)"),
            warmup, samples, || {
                build_cost_inputs_into(&f.jobs, &view, &mut scalar_ws.inputs,
                                       &mut replicas);
                schedule_step_scalar_into(&scalar_ws.inputs, &w,
                                          &mut scalar_ws.out);
                black_box(scalar_ws.out.best_total[0]);
            });
        r_scalar.throughput(nj as f64, "jobs");

        let mut ws = CostWorkspace::new();
        let r_soa = bench(
            &format!("soa        J={nj:<4} S={ns:<3} (workspace, vectorized)"),
            warmup, samples, || {
                build_cost_inputs_into(&f.jobs, &view, &mut ws.inputs,
                                       &mut replicas);
                engine
                    .schedule_step_into(&ws.inputs, &w, &mut ws.out)
                    .unwrap();
                black_box(ws.out.best_total[0]);
            });
        r_soa.throughput(nj as f64, "jobs");
        println!("  └ soa vs scalar: {:.2}x · vs old-style: {:.2}x",
                 r_scalar.mean_ns() / r_soa.mean_ns(),
                 r_old.mean_ns() / r_soa.mean_ns());

        // Cross-check: all three paths agree on every argmin, and the
        // scalar/SoA float matrices are bit-identical (the
        // kernel_differential.rs contract, re-asserted on bench inputs).
        let inp = build_cost_inputs(&f.jobs, &view);
        let old = engine.schedule_step(&inp, &w).unwrap();
        for out in [&scalar_ws.out, &ws.out] {
            assert_eq!(old.best_total, out.best_total);
            assert_eq!(old.best_compute, out.best_compute);
            assert_eq!(old.best_data, out.best_data);
        }
        assert_eq!(bits(&scalar_ws.out.total), bits(&ws.out.total));
        assert_eq!(bits(&scalar_ws.out.net), bits(&ws.out.net));
        assert_eq!(bits(&scalar_ws.out.dtc), bits(&ws.out.dtc));
        assert_eq!(bits(&scalar_ws.out.comp), bits(&ws.out.comp));

        results.push(ShapeResult {
            nj,
            ns,
            old_rounds_per_s: 1e9 / r_old.mean_ns(),
            scalar_rounds_per_s: 1e9 / r_scalar.mean_ns(),
            soa_rounds_per_s: 1e9 / r_soa.mean_ns(),
            soa_speedup_vs_scalar: r_scalar.mean_ns() / r_soa.mean_ns(),
        });
        closing_events_per_s = nj as f64 / (r_soa.mean_ns() / 1e9);
    }
    println!("matchmaker events/s (J=1024 S=500, soa): {:.0}",
             closing_events_per_s);
    if let Some(path) = json_path {
        write_json(&path, smoke, &results);
    }
}
