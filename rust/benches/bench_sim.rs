//! Bench: DES substrate — raw event-queue throughput and whole-world
//! simulation rate (events/s), the L3 backbone.

mod common;
use common::{bench, black_box};

use diana::config::presets;
use diana::coordinator::{generate_workload, run_simulation_with};
use diana::sim::EventQueue;

fn main() {
    println!("== bench_sim: DES event throughput ==");

    // Raw heap: schedule+pop churn at three queue depths.
    for depth in [1_000usize, 10_000, 100_000] {
        let r = bench(&format!("event heap churn depth={depth}"), 3, 30,
                      || {
            let mut q = EventQueue::new();
            for i in 0..depth {
                q.schedule(i as f64 * 0.5, i);
            }
            let mut acc = 0usize;
            while let Some((_, e)) = q.pop() {
                acc = acc.wrapping_add(e);
            }
            black_box(acc);
        });
        r.throughput(2.0 * depth as f64, "events");
    }

    // Whole-world: the §XI testbed with 500 jobs.
    let mut cfg = presets::paper_testbed();
    cfg.workload.jobs = 500;
    cfg.workload.bulk_size = 25;
    cfg.workload.cpu_sec_median = 120.0;
    let subs = generate_workload(&cfg);
    let mut events = 0u64;
    let r = bench("world run 500 jobs (diana)", 1, 10, || {
        let (w, _) = run_simulation_with(&cfg, subs.clone()).unwrap();
        events = w.events_processed();
        black_box(&w);
    });
    r.throughput(events as f64, "events");
    println!("  ({events} DES events per run)");
}
