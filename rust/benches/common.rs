//! Shared micro-bench harness for the `harness = false` benches (the
//! offline crate set has no criterion). Warmup + N timed samples;
//! reports mean / p50 / p95 / min plus a derived throughput line.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    fn pct(&self, p: f64) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        s[lo] * (1.0 - frac) + s[hi] * frac
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn report(&self) {
        let fmt = |ns: f64| {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        println!(
            "{:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}  (n={})",
            self.name,
            fmt(self.mean_ns()),
            fmt(self.pct(50.0)),
            fmt(self.pct(95.0)),
            fmt(self.pct(0.0)),
            self.samples_ns.len()
        );
    }

    /// Print an items-per-second line derived from the mean.
    pub fn throughput(&self, items: f64, unit: &str) {
        let per_s = items / (self.mean_ns() / 1e9);
        println!("{:<44} {:>14.0} {unit}/s", format!("  └ {}", self.name),
                 per_s);
    }
}

/// Run `f` for `warmup` + `samples` iterations, timing each sample.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_nanos() as f64);
    }
    let r = BenchResult { name: name.to_string(), samples_ns: out };
    r.report();
    r
}

/// `black_box` without nightly: volatile read defeats const-prop.
#[inline]
pub fn black_box<T>(x: T) -> T {
    unsafe {
        let y = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        y
    }
}
