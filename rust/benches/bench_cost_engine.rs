//! Bench: one §V matchmaking round (the L1 kernel's job) — rust mirror
//! vs the AOT Pallas/XLA artifact via PJRT, across batch shapes. This is
//! the per-scheduling-round hot path of the coordinator.

mod common;
use common::{bench, black_box};

use diana::cost::{CostEngine, CostInputs, RustEngine, Weights};
use diana::util::Pcg64;

fn inputs(rng: &mut Pcg64, nj: usize, ns: usize) -> CostInputs {
    let mut inp = CostInputs::new(nj, ns);
    for j in 0..nj {
        inp.set_job_row(j, &[
            rng.uniform(0.0, 30_000.0) as f32,
            rng.uniform(0.0, 2_000.0) as f32,
            rng.uniform(1.0, 200.0) as f32,
            rng.uniform(1.0, 7200.0) as f32,
            0.0,
            0.0,
        ]);
    }
    for s in 0..ns {
        inp.set_site_row(s, &[
            rng.below(500) as f32,
            rng.uniform(1.0, 600.0) as f32,
            rng.next_f64() as f32,
            rng.uniform(10.0, 10_000.0) as f32,
            rng.uniform(0.0, 0.1) as f32,
            1.0,
            0.0,
            0.0,
        ]);
    }
    for v in inp.link_bw.iter_mut() {
        *v = rng.uniform(1.0, 10_000.0) as f32;
    }
    for v in inp.link_loss.iter_mut() {
        *v = rng.uniform(0.0, 0.1) as f32;
    }
    inp
}

fn main() {
    println!("== bench_cost_engine: J×S fused cost matrix ==");
    let mut rng = Pcg64::new(1);
    let w = Weights { q_total: 500.0, ..Weights::default() };

    for (nj, ns) in [(25, 5), (256, 32), (1024, 32)] {
        let inp = inputs(&mut rng, nj, ns);
        let mut rust = RustEngine::new();
        let r = bench(&format!("rust  schedule_step {nj}x{ns}"), 20, 200,
                      || {
            black_box(rust.schedule_step(&inp, &w).unwrap());
        });
        r.throughput(nj as f64, "jobs");
    }

    if cfg!(feature = "xla") && diana::runtime::artifacts_available() {
        let mut xla = diana::runtime::XlaEngine::load_default().unwrap();
        for (nj, ns) in [(1, 32), (25, 5), (256, 32), (1024, 32)] {
            let inp = inputs(&mut rng, nj, ns);
            let r = bench(&format!("xla   schedule_step {nj}x{ns}"), 5, 50,
                          || {
                black_box(xla.schedule_step(&inp, &w).unwrap());
            });
            r.throughput(nj as f64, "jobs");
        }
    } else {
        println!("(xla feature off or artifacts missing — xla engine skipped)");
    }
}
