//! Bench: one end-to-end timing per paper table/figure — how long each
//! §XI reproduction takes to regenerate (and that it still SUCCEEDS).

mod common;
use common::bench;

fn main() {
    println!("== bench_figures: end-to-end figure regeneration ==");
    // Cheap, closed-form figures: tight loop.
    for fig in ["fig3", "fig6"] {
        bench(&format!("repro {fig}"), 2, 20, || {
            diana::repro::run_figure(fig).unwrap();
        });
    }
    // Simulation-backed figures: one timed run each.
    for fig in ["fig4", "fig9", "fig10", "fig11"] {
        bench(&format!("repro {fig}"), 0, 3, || {
            diana::repro::run_figure(fig).unwrap();
        });
    }
    // The fig7/8 sweep is the heavyweight (12 full simulations).
    bench("repro fig7 (6-point sweep, 2 policies)", 0, 1, || {
        diana::repro::run_figure("fig7").unwrap();
    });
}
