//! Integration tests: full runs across layers and policies.

use diana::config::{presets, EngineKind, GridConfig, Policy};
use diana::coordinator::{generate_workload, run_simulation,
                         run_simulation_with};
use diana::metrics::JobRecord;

fn small(jobs: usize) -> GridConfig {
    let mut cfg = presets::paper_testbed();
    cfg.workload.jobs = jobs;
    cfg.workload.bulk_size = 25;
    cfg.workload.cpu_sec_median = 60.0;
    cfg.workload.cpu_sec_sigma = 0.4;
    cfg.workload.in_mb_median = 100.0;
    cfg
}

#[test]
fn every_policy_completes_the_same_workload() {
    let cfg = small(100);
    let subs = generate_workload(&cfg);
    for policy in [Policy::Diana, Policy::FcfsBroker, Policy::Greedy,
                   Policy::DataLocal, Policy::Random] {
        let mut c = cfg.clone();
        c.scheduler.policy = policy;
        let (_, r) = run_simulation_with(&c, subs.clone()).unwrap();
        assert_eq!(r.jobs, 100, "{policy:?} lost jobs");
        assert!(r.makespan_s > 0.0);
    }
}

#[test]
fn diana_beats_fcfs_on_data_heavy_workload() {
    let mut cfg = small(300);
    cfg.workload.in_mb_median = 1000.0;
    cfg.workload.frac_compute = 0.1;
    cfg.workload.frac_data = 0.7;
    cfg.workload.frac_both = 0.2;
    let subs = generate_workload(&cfg);
    let (_, diana) = run_simulation_with(&cfg, subs.clone()).unwrap();
    let mut fcfs = cfg.clone();
    fcfs.scheduler.policy = Policy::FcfsBroker;
    let (_, fcfs) = run_simulation_with(&fcfs, subs).unwrap();
    assert!(
        diana.turnaround.mean < fcfs.turnaround.mean,
        "diana {:.0}s !< fcfs {:.0}s",
        diana.turnaround.mean,
        fcfs.turnaround.mean
    );
}

#[test]
fn lifecycle_timestamps_are_ordered_for_every_job() {
    let (world, _) = run_simulation(&small(120)).unwrap();
    let mut n = 0;
    for r in world.recorder.completed_records() {
        assert!(r.submit <= r.placed);
        assert!(r.placed <= r.started);
        assert!(r.started < r.finished);
        assert!(r.finished <= r.delivered);
        n += 1;
    }
    assert_eq!(n, 120);
}

#[test]
fn conservation_no_job_executes_twice() {
    let (world, report) = run_simulation(&small(150)).unwrap();
    assert_eq!(report.jobs, 150);
    assert_eq!(world.recorder.n_tracked(), 150);
    // Sum of per-site executed events equals total jobs.
    let executed: f64 = (0..5)
        .map(|s| {
            world.recorder.site_series(s).executed.series().iter()
                .map(|p| p.1 * 60.0)
                .sum::<f64>()
        })
        .sum();
    assert!((executed - 150.0).abs() < 1.0, "executed sum {executed}");
}

#[test]
fn seeds_change_outcomes_but_runs_are_reproducible() {
    let mut a = small(60);
    a.seed = 1;
    let mut b = small(60);
    b.seed = 2;
    let (_, ra1) = run_simulation(&a).unwrap();
    let (_, ra2) = run_simulation(&a).unwrap();
    let (_, rb) = run_simulation(&b).unwrap();
    assert_eq!(ra1.makespan_s, ra2.makespan_s);
    assert_ne!(ra1.makespan_s, rb.makespan_s);
}

#[test]
fn xla_engine_drives_identical_schedule() {
    if !cfg!(feature = "xla") || !diana::runtime::artifacts_available() {
        eprintln!("skipping: xla feature off or artifacts not built");
        return;
    }
    let cfg = small(80);
    let subs = generate_workload(&cfg);
    let mut xla = cfg.clone();
    xla.scheduler.engine = EngineKind::Xla;
    let (_, rx) = run_simulation_with(&xla, subs.clone()).unwrap();
    let mut rust = cfg;
    rust.scheduler.engine = EngineKind::Rust;
    let (_, rr) = run_simulation_with(&rust, subs).unwrap();
    assert_eq!(rx.jobs, rr.jobs);
    assert_eq!(rx.makespan_s, rr.makespan_s, "engines disagree");
    assert_eq!(rx.migrations, rr.migrations);
    assert_eq!(rx.queue_time.mean, rr.queue_time.mean);
}

#[test]
fn cms_tier_grid_respects_data_gravity() {
    let mut cfg = presets::cms_tier_grid();
    cfg.workload.jobs = 200;
    cfg.workload.bulk_size = 50;
    cfg.workload.cpu_sec_median = 300.0;
    let (world, report) = run_simulation(&cfg).unwrap();
    assert_eq!(report.jobs, 200);
    // Data-heavy CMS jobs should mostly execute at the data-rich tiers
    // (T0/T1 = sites 0–2 hold 100% of datasets between them).
    let at_data_tiers = world
        .recorder
        .completed_records()
        .filter(|r| r.exec_site <= 2)
        .count();
    assert!(
        at_data_tiers * 2 > 200,
        "only {at_data_tiers}/200 ran at data tiers"
    );
}

#[test]
fn failure_injection_dead_site_is_never_used() {
    use diana::cost::RustEngine;
    use diana::scheduler::make_picker;
    use diana::sim::World;

    let cfg = small(60);
    let picker = make_picker(
        cfg.scheduler.policy,
        Box::new(RustEngine::new()),
        &cfg.scheduler,
        cfg.seed,
    );
    let mut world = World::new(cfg.clone(), picker,
                               Box::new(RustEngine::new()));
    world.set_alive(1, false);
    world.load_submissions(generate_workload(&cfg));
    world.run().unwrap();
    for r in world.recorder.completed_records() {
        assert_ne!(r.exec_site, 1);
    }
}

#[test]
fn trace_replay_reproduces_simulation() {
    let cfg = small(50);
    let subs = generate_workload(&cfg);
    let dir = std::env::temp_dir().join("diana-integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("replay.csv");
    diana::workload::write_trace(&path, &subs).unwrap();
    let replayed = diana::workload::read_trace(&path).unwrap();
    let (_, a) = run_simulation_with(&cfg, subs).unwrap();
    let (_, b) = run_simulation_with(&cfg, replayed).unwrap();
    assert_eq!(a.makespan_s, b.makespan_s);
    assert_eq!(a.queue_time.mean, b.queue_time.mean);
    std::fs::remove_file(&path).ok();
}

#[test]
fn summary_metrics_are_internally_consistent() {
    let (world, report) = run_simulation(&small(90)).unwrap();
    // Turnaround ≥ queue + exec for every job (delivery adds time).
    for r in world.recorder.completed_records() {
        let lhs = r.turnaround();
        let rhs = r.queue_time() + r.exec_time();
        assert!(lhs + 1e-6 >= rhs, "{lhs} < {rhs}");
    }
    assert!(report.turnaround.mean + 1e-6
        >= report.queue_time.mean);
    assert_eq!(report.jobs, world.recorder.n_completed());
}

#[test]
fn overload_produces_migrations_and_balanced_finish() {
    let mut cfg = small(200);
    cfg.workload.bulk_size = 200;
    cfg.workload.arrival_rate = 100.0;
    cfg.scheduler.congestion_thrs = 0.05;
    cfg.scheduler.migration_period_s = 10.0;
    // All 200 jobs pinned to site 0 (a flood).
    let mut subs = generate_workload(&cfg);
    for s in &mut subs {
        s.group.pin_site = Some(0);
    }
    let (world, report) = run_simulation_with(&cfg, subs).unwrap();
    assert_eq!(report.jobs, 200);
    assert!(report.migrations > 0, "flood produced no migration");
    // At least two sites participated in execution.
    let sites_used: std::collections::BTreeSet<usize> = world
        .recorder
        .completed_records()
        .map(|r| r.exec_site)
        .collect();
    assert!(sites_used.len() >= 2, "all work stayed at {sites_used:?}");
}
