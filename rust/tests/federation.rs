//! Federation acceptance properties (ISSUE 3):
//!
//! * `--federation 1` reproduces the central leader bit-for-bit;
//! * federated sweep output is stable across `-j` thread counts;
//! * bulk load on a weak partition provably delegates to a strong one;
//! * the built-in central-vs-federated scenario shows ≥ 1 delegated job
//!   and a measurable makespan difference between the two modes.

use diana::config::{presets, PeerTopology, Policy};
use diana::coordinator::{generate_workload, run_simulation,
                         run_simulation_with};
use diana::cost::RustEngine;
use diana::job::UserId;
use diana::scenario::{library, run_sweep, SweepSpec};
use diana::scheduler::make_picker;
use diana::sim::World;
use diana::util::Pcg64;
use diana::workload::WorkloadGen;

/// `federation.peers = 1` must be indistinguishable from the central
/// leader on the same seed and workload: same event count, same metric
/// distributions, field-for-field — the degenerate federation runs the
/// same code path with nothing to gossip and nobody to delegate to.
#[test]
fn one_peer_federation_is_bit_identical_to_central() {
    let mut central_cfg = presets::uniform_grid(5, 4);
    central_cfg.workload.jobs = 60;
    central_cfg.workload.bulk_size = 12;
    central_cfg.workload.cpu_sec_median = 90.0;
    let mut fed_cfg = central_cfg.clone();
    fed_cfg.federation.peers = 1;

    let subs = generate_workload(&central_cfg);
    let (_, central) = run_simulation_with(&central_cfg, subs.clone()).unwrap();
    let (world, fed) = run_simulation_with(&fed_cfg, subs).unwrap();

    assert!(world.federation().is_some(), "1 peer still builds the runtime");
    assert_eq!(fed.delegations, 0);
    // Debug-format the whole report: every field (all Summary tails,
    // event counts, counters) must match byte for byte.
    assert_eq!(format!("{central:?}"), format!("{fed:?}"));
}

/// The same equivalence through the generated-workload front door (what
/// `diana run --federation 1` does vs plain `diana run`).
#[test]
fn one_peer_federation_matches_central_via_run_simulation() {
    let mut cfg = presets::paper_testbed();
    cfg.workload.jobs = 50;
    let (_, a) = run_simulation(&cfg).unwrap();
    cfg.federation.peers = 1;
    let (_, b) = run_simulation(&cfg).unwrap();
    assert_eq!(format!("{a:?}"), format!("{b:?}"));
}

/// A 4-peer federated sweep is byte-identical for any `-j`: runs are
/// self-contained, the federation state lives per-world, and nothing
/// leaks across workers.
#[test]
fn four_peer_sweep_is_stable_across_thread_counts() {
    let spec = SweepSpec::from_str_named(
        "name = \"fed4\"\npreset = \"uniform-8x2\"\nbase_seed = 31\n\
         repeats = 2\n\
         [axes]\nfederation.peers = [4]\n\
         [set]\njobs = 40\nbulk_size = 10\ncpu_sec_median = 60.0\n\
         cpu_sec_sigma = 0.3\nexe_mb = 1.0\n\
         federation.gossip_period_s = 20.0\n",
        "fed4",
    )
    .unwrap();
    let a = run_sweep(&spec, 1).unwrap();
    let b = run_sweep(&spec, 4).unwrap();
    assert_eq!(a.runs_csv(), b.runs_csv());
    assert_eq!(a.aggregate_csv(), b.aggregate_csv());
    assert_eq!(a.to_json(), b.to_json());
    for r in &a.runs {
        assert_eq!(r.jobs, 40, "run {} incomplete", r.index);
    }
}

fn weak_west_strong_east_cfg() -> diana::config::GridConfig {
    // Peers over 8 sites: {0,1} {2,3} {4,5} {6,7}; only peer 3 has
    // capacity. Compute-only jobs make the §IV cost row queue-dominated,
    // so a 20-job bulk at site 0 *must* beat the 0.8 threshold east.
    let mut cfg = presets::uniform_grid(8, 1);
    cfg.sites[6].cpus = 24;
    cfg.sites[7].cpus = 24;
    cfg.workload.frac_compute = 1.0;
    cfg.workload.frac_data = 0.0;
    cfg.workload.frac_both = 0.0;
    cfg.workload.max_procs = 1;
    cfg.workload.exe_mb = 1.0;
    cfg.workload.cpu_sec_median = 60.0;
    cfg.workload.cpu_sec_sigma = 0.2;
    cfg.federation.peers = 4;
    cfg.federation.topology = PeerTopology::Flat;
    cfg.federation.gossip_period_s = 20.0;
    cfg.validate().unwrap();
    cfg
}

fn federated_world(cfg: diana::config::GridConfig) -> World {
    let picker = make_picker(
        cfg.scheduler.policy,
        Box::new(RustEngine::new()),
        &cfg.scheduler,
        cfg.seed,
    );
    World::new(cfg, picker, Box::new(RustEngine::new()))
}

/// Deterministic delegation: every bulk submitted at the starved western
/// partition is forwarded east, lands on the strong sites, and the run
/// still delivers everything.
#[test]
fn bulk_load_on_weak_partition_delegates_to_strong_peer() {
    let mut world = federated_world(weak_west_strong_east_cfg());
    let mut rng = Pcg64::new(2);
    world.catalog =
        diana::data::Catalog::from_config(&world.cfg, &mut rng);
    let cat = world.catalog.clone();
    let mut gen = WorkloadGen::new(4);
    let subs: Vec<_> = (0..3)
        .map(|i| {
            gen.bulk(&world.cfg, &cat, UserId(i), 0, i as f64 * 5.0, 20)
        })
        .collect();
    world.load_submissions(subs);
    world.run().unwrap();
    assert_eq!(world.completion(), 1.0);
    assert!(
        world.recorder.delegations >= 20,
        "expected at least the first bulk delegated, got {}",
        world.recorder.delegations
    );
    let fed = world.federation().unwrap();
    assert!(fed.forwards > 0);
    // The delegated jobs really execute in the eastern partition.
    let east = world
        .recorder
        .completed_records()
        .filter(|r| r.exec_site >= 6)
        .count();
    assert!(east >= 20, "only {east} jobs ran east");
}

/// Policy-independence: the delegation layer rides on the generic
/// `SitePicker::site_costs` contract, so baselines federate too.
#[test]
fn fcfs_policy_also_federates_and_completes() {
    let mut cfg = weak_west_strong_east_cfg();
    cfg.scheduler.policy = Policy::FcfsBroker;
    let mut world = federated_world(cfg);
    let mut rng = Pcg64::new(3);
    world.catalog =
        diana::data::Catalog::from_config(&world.cfg, &mut rng);
    let cat = world.catalog.clone();
    let mut gen = WorkloadGen::new(5);
    let subs = vec![gen.bulk(&world.cfg, &cat, UserId(0), 0, 0.0, 20)];
    world.load_submissions(subs);
    world.run().unwrap();
    assert_eq!(world.completion(), 1.0);
}

/// Acceptance: the shipped scenario demonstrates ≥ 1 delegated job in
/// federated mode, zero in central mode, and a measurable makespan
/// difference between the two matrix points.
#[test]
fn central_vs_federated_scenario_delegates_and_shifts_makespan() {
    let spec = library::load("central-vs-federated").unwrap();
    let rep = run_sweep(&spec, 2).unwrap();
    assert_eq!(rep.runs.len(), 2);
    let central = &rep.runs[0];
    let federated = &rep.runs[1];
    assert_eq!(central.labels[0], ("federation.peers".into(), "1".into()));
    assert_eq!(federated.labels[0], ("federation.peers".into(), "4".into()));
    assert_eq!(central.jobs, 160);
    assert_eq!(federated.jobs, 160);
    assert_eq!(central.delegations, 0, "central mode must not delegate");
    assert!(
        federated.delegations > 0,
        "federated bulk load produced no delegations"
    );
    let diff = (central.makespan_s - federated.makespan_s).abs();
    assert!(
        diff > 1e-6,
        "central and federated makespans are indistinguishable: {} vs {}",
        central.makespan_s,
        federated.makespan_s
    );
}

/// Peer faults steer load without losing jobs: with the eastern peer's
/// scheduler dead, western bulks can no longer delegate east and the
/// federation still completes; after `peer-up` it can delegate again.
#[test]
fn peer_fault_scenario_completes_without_the_strong_peer() {
    use diana::scenario::faults::{FaultEvent, FaultKind, FaultPlan};
    let mut world = federated_world(weak_west_strong_east_cfg());
    let mut rng = Pcg64::new(8);
    world.catalog =
        diana::data::Catalog::from_config(&world.cfg, &mut rng);
    let plan = FaultPlan {
        events: vec![FaultEvent {
            at: 0.0,
            kind: FaultKind::PeerDown { peer: 3 },
        }],
    };
    world.load_faults(&plan).unwrap();
    let cat = world.catalog.clone();
    let mut gen = WorkloadGen::new(6);
    let subs = vec![gen.bulk(&world.cfg, &cat, UserId(0), 0, 1.0, 10)];
    world.load_submissions(subs);
    world.run().unwrap();
    assert_eq!(world.completion(), 1.0);
    // Peer 3 is unreachable: nothing may execute on its sites.
    for r in world.recorder.completed_records() {
        assert!(r.exec_site < 6, "job ran on dead peer's site");
    }
}
