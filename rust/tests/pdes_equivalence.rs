//! Serial ≡ parallel (PDES) equivalence suite.
//!
//! The conservative parallel engine (`sim::pdes`, `--sim-threads N`)
//! must be **behavior-preserving**: for every eligible scenario the
//! sharded run's reports must be byte-identical to the serial
//! reference (`--sim-threads 1`), for every thread count. The check
//! runs a randomized fixture sweep — two topologies × flat/tree/ring
//! peer wiring × seeds × fault plans, plus the PR 9 envelope widening:
//! site-lifecycle fault plans, central (peers < 2) runs and streamed
//! sources — through the real sweep runner and diffs the rendered
//! runs/aggregate CSVs and JSON (the same artifacts ci.sh compares
//! between thread counts), exactly like the cached-vs-paranoid harness
//! in `tests/equivalence.rs`.

use diana::coordinator::generate_workload;
use diana::scenario::{run_one, SweepReport, SweepSpec};
use diana::sim::{
    try_run_parallel, try_run_parallel_streamed, PdesDecline, PdesOutcome,
    PdesStreamOutcome,
};

/// Run one spec's matrix serially, then once per parallel thread
/// count, and assert the serialized reports match byte-for-byte.
fn assert_threads_equivalence(spec_toml: &str, name: &str) {
    let spec = SweepSpec::from_str_named(spec_toml, name).unwrap();
    let runs = spec.expand().unwrap();
    assert!(!runs.is_empty(), "{name}: empty matrix");
    let mut serial = Vec::with_capacity(runs.len());
    for run in &runs {
        let mut r = run.clone();
        r.cfg.sim.threads = 1;
        serial.push(run_one(&r, &spec.faults).unwrap());
    }
    let a = SweepReport::build(&spec, serial);
    for threads in [2usize, 4, 8] {
        let mut parallel = Vec::with_capacity(runs.len());
        for run in &runs {
            let mut r = run.clone();
            r.cfg.sim.threads = threads;
            parallel.push(run_one(&r, &spec.faults).unwrap());
        }
        let b = SweepReport::build(&spec, parallel);
        assert_eq!(
            a.runs_csv(),
            b.runs_csv(),
            "{name}: runs CSV diverged at --sim-threads {threads}"
        );
        assert_eq!(
            a.aggregate_csv(),
            b.aggregate_csv(),
            "{name}: aggregate CSV diverged at --sim-threads {threads}"
        );
        assert_eq!(
            a.to_json(),
            b.to_json(),
            "{name}: JSON diverged at --sim-threads {threads}"
        );
    }
}

/// Guard against a vacuous pass: the fixture configs must actually be
/// inside the parallel envelope (a silently declined run would compare
/// serial against serial). Checks every run in the matrix, eager and
/// streamed alike, at every compared thread count.
fn assert_parallel_path_taken(spec_toml: &str, name: &str) {
    let spec = SweepSpec::from_str_named(spec_toml, name).unwrap();
    let runs = spec.expand().unwrap();
    for run in &runs {
        for threads in [2usize, 4, 8] {
            let mut cfg = run.cfg.clone();
            cfg.sim.threads = threads;
            if cfg.workload.source.is_streaming() {
                match try_run_parallel_streamed(&cfg, &spec.faults).unwrap()
                {
                    PdesStreamOutcome::Done(_, report) => {
                        assert!(report.pdes_parallel);
                        assert!(report.pdes_windows > 0);
                    }
                    PdesStreamOutcome::Declined(reason) => panic!(
                        "{name} run {} declined the parallel path at \
                         --sim-threads {threads}: {reason}",
                        run.index
                    ),
                }
            } else {
                let subs = generate_workload(&cfg);
                match try_run_parallel(&cfg, subs, &spec.faults).unwrap() {
                    PdesOutcome::Done(_, report) => {
                        assert!(report.pdes_parallel);
                        assert!(report.pdes_windows > 0);
                    }
                    PdesOutcome::Declined { reason, .. } => panic!(
                        "{name} run {} declined the parallel path at \
                         --sim-threads {threads}: {reason}",
                        run.index
                    ),
                }
            }
        }
    }
}

fn federated_spec(peer_topology: &str) -> String {
    format!(
        "name = \"pdes-eq-{peer_topology}\"\n\
         preset = \"uniform-6x4\"\n\
         repeats = 2\n\
         base_seed = 31\n\
         [axes]\n\
         federation.peers = [2, 3]\n\
         [set]\n\
         jobs = 60\n\
         bulk_size = 12\n\
         cpu_sec_median = 120.0\n\
         federation.topology = \"{peer_topology}\"\n\
         federation.gossip_period_s = 20.0\n"
    )
}

#[test]
fn flat_federation_matches_serial_bitwise() {
    assert_parallel_path_taken(&federated_spec("flat"), "pdes-eq-flat");
    assert_threads_equivalence(&federated_spec("flat"), "pdes-eq-flat");
}

#[test]
fn tree_federation_matches_serial_bitwise() {
    assert_threads_equivalence(&federated_spec("tree"), "pdes-eq-tree");
}

#[test]
fn ring_federation_matches_serial_bitwise() {
    assert_threads_equivalence(&federated_spec("ring"), "pdes-eq-ring");
}

#[test]
fn paper_testbed_matches_serial_bitwise() {
    // The heterogeneous paper topology across a seed axis: uneven
    // links and capacities stress the lookahead bound and the
    // delegation/deliver latency extraction.
    let spec = "name = \"pdes-eq-testbed\"\n\
                preset = \"paper-testbed\"\n\
                base_seed = 13\n\
                [axes]\n\
                seed = [3, 9, 27]\n\
                [set]\n\
                jobs = 50\n\
                bulk_size = 10\n\
                cpu_sec_median = 90.0\n\
                federation.peers = 2\n\
                federation.gossip_period_s = 25.0\n";
    assert_parallel_path_taken(spec, "pdes-eq-testbed");
    assert_threads_equivalence(spec, "pdes-eq-testbed");
}

#[test]
fn central_runs_match_serial_bitwise() {
    // Newly eligible class: no federation (peers = 0) and the
    // degenerate 1-peer federation — both shard by contiguous site
    // block with the single scheduler's placement rounds replayed at
    // admission barriers.
    let spec = "name = \"pdes-eq-central\"\n\
                preset = \"uniform-6x4\"\n\
                base_seed = 23\n\
                [axes]\n\
                federation.peers = [0, 1]\n\
                seed = [3, 14]\n\
                [set]\n\
                jobs = 60\n\
                bulk_size = 12\n\
                cpu_sec_median = 120.0\n";
    assert_parallel_path_taken(spec, "pdes-eq-central");
    assert_threads_equivalence(spec, "pdes-eq-central");
}

#[test]
fn streamed_sources_match_serial_bitwise() {
    // Newly eligible class: lazily pulled workloads. The coordinator
    // owns the refill chain and admits each submission at a
    // window-aligned barrier — central and federated.
    let spec = "name = \"pdes-eq-streamed\"\n\
                preset = \"uniform-6x4\"\n\
                base_seed = 29\n\
                [axes]\n\
                federation.peers = [0, 2]\n\
                [set]\n\
                source = \"streamed\"\n\
                jobs = 60\n\
                bulk_size = 12\n\
                cpu_sec_median = 120.0\n\
                federation.gossip_period_s = 20.0\n";
    assert_parallel_path_taken(spec, "pdes-eq-streamed");
    assert_threads_equivalence(spec, "pdes-eq-streamed");
}

#[test]
fn faulted_federation_matches_serial_bitwise() {
    // Every fault kind the parallel path replicates: link degradation,
    // a WAN partition, its heal, and a monitor blackout. Fault times
    // deliberately sit on monitor/migration ticks — the coordinator's
    // tie discipline (faults first) must match the serial seq order.
    let spec = "name = \"pdes-eq-faults\"\n\
                preset = \"uniform-6x4\"\n\
                base_seed = 17\n\
                [axes]\n\
                seed = [5, 21]\n\
                [set]\n\
                jobs = 60\n\
                bulk_size = 12\n\
                cpu_sec_median = 120.0\n\
                federation.peers = 3\n\
                federation.gossip_period_s = 20.0\n\
                [[fault]]\n\
                at = 30.0\n\
                kind = \"link-degrade\"\n\
                from = \"s0\"\n\
                to = \"s2\"\n\
                rtt_factor = 8.0\n\
                loss_add = 0.03\n\
                capacity_factor = 0.2\n\
                [[fault]]\n\
                at = 60.0\n\
                kind = \"partition\"\n\
                members = [\"s4\", \"s5\"]\n\
                rtt_ms = 400.0\n\
                loss = 0.05\n\
                capacity_mbps = 5.0\n\
                [[fault]]\n\
                at = 240.0\n\
                kind = \"heal\"\n\
                [[fault]]\n\
                at = 300.0\n\
                kind = \"monitor-blackout\"\n\
                duration_s = 120.0\n";
    assert_parallel_path_taken(spec, "pdes-eq-faults");
    assert_threads_equivalence(spec, "pdes-eq-faults");
}

#[test]
fn site_fault_plans_match_serial_bitwise() {
    // Newly eligible class: site-lifecycle faults. A site dies at
    // t=20 with queued work (waking the §IX force-migration escape
    // hatch at the next sweep) and recovers at t=200 — replayed
    // liveness plus the owner-only recovery kick must reproduce the
    // serial stream bitwise, federated and central.
    let spec = "name = \"pdes-eq-sitefault\"\n\
                preset = \"uniform-6x4\"\n\
                base_seed = 19\n\
                [axes]\n\
                federation.peers = [0, 2]\n\
                [set]\n\
                jobs = 40\n\
                bulk_size = 10\n\
                cpu_sec_median = 60.0\n\
                [[fault]]\n\
                at = 20.0\n\
                kind = \"site-down\"\n\
                site = \"s1\"\n\
                [[fault]]\n\
                at = 200.0\n\
                kind = \"site-up\"\n\
                site = \"s1\"\n";
    assert_parallel_path_taken(spec, "pdes-eq-sitefault");
    assert_threads_equivalence(spec, "pdes-eq-sitefault");
}

#[test]
fn remaining_declines_fall_back_with_named_reasons() {
    // Peer-lifecycle faults stay outside the envelope: a dead home
    // peer re-routes admissions across partitions. The decline must be
    // named — and the scenario must still match serial trivially.
    let spec_toml = "name = \"pdes-eq-peerdown\"\n\
                     preset = \"uniform-6x4\"\n\
                     base_seed = 37\n\
                     [set]\n\
                     jobs = 40\n\
                     bulk_size = 10\n\
                     cpu_sec_median = 60.0\n\
                     federation.peers = 2\n\
                     [[fault]]\n\
                     at = 25.0\n\
                     kind = \"peer-down\"\n\
                     peer = 1\n\
                     [[fault]]\n\
                     at = 250.0\n\
                     kind = \"peer-up\"\n\
                     peer = 1\n";
    let spec =
        SweepSpec::from_str_named(spec_toml, "pdes-eq-peerdown").unwrap();
    let runs = spec.expand().unwrap();
    let mut cfg = runs[0].cfg.clone();
    cfg.sim.threads = 4;
    let subs = generate_workload(&cfg);
    let n = subs.len();
    match try_run_parallel(&cfg, subs, &spec.faults).unwrap() {
        PdesOutcome::Declined { subs, reason } => {
            assert_eq!(reason, PdesDecline::PeerFaultPlan);
            assert_eq!(subs.len(), n, "workload must come back intact");
        }
        PdesOutcome::Done(..) => {
            panic!("peer-fault scenario must not take the PDES path")
        }
    }
    assert_threads_equivalence(spec_toml, "pdes-eq-peerdown");
}
