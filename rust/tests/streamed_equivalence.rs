//! Streamed ≡ eager equivalence, end to end through the public
//! assembly entry points (`run_simulation` / `run_simulation_streamed`
//! — not the world-test helpers): the lazy `--source streamed` route
//! must reproduce the eager report **bit for bit** on central,
//! federated and fault-injected runs, with and without spilling. Any
//! drift means the SourceRefill chain reordered events, the generator
//! replay diverged, or the spill merge lost a bit — all of which this
//! suite exists to catch before a million-job run hides them.

use diana::config::{presets, GridConfig, SourceMode};
use diana::coordinator::{
    generate_workload, run_simulation, run_simulation_streamed,
    run_simulation_with_faults, RunReport,
};
use diana::metrics::SummaryStats;
use diana::scenario::{FaultEvent, FaultKind, FaultPlan};

/// Field-for-field, bit-for-bit report comparison. Floats are compared
/// as raw bits: "close" is drift, and drift compounds at 10^6 jobs.
fn assert_reports_identical(a: &RunReport, b: &RunReport, ctx: &str) {
    assert_eq!(a.policy, b.policy, "{ctx}: policy");
    assert_eq!(a.jobs, b.jobs, "{ctx}: jobs");
    assert_eq!(a.events, b.events, "{ctx}: DES event count");
    assert_eq!(
        a.makespan_s.to_bits(),
        b.makespan_s.to_bits(),
        "{ctx}: makespan"
    );
    assert_eq!(
        a.throughput_jobs_per_s.to_bits(),
        b.throughput_jobs_per_s.to_bits(),
        "{ctx}: throughput"
    );
    for (name, sa, sb) in [
        ("queue_time", &a.queue_time, &b.queue_time),
        ("exec_time", &a.exec_time, &b.exec_time),
        ("turnaround", &a.turnaround, &b.turnaround),
        ("response_time", &a.response_time, &b.response_time),
    ] {
        assert_summaries_identical(sa, sb, ctx, name);
    }
    assert_eq!(a.migrations, b.migrations, "{ctx}: migrations");
    assert_eq!(a.groups_split, b.groups_split, "{ctx}: groups_split");
    assert_eq!(a.groups_whole, b.groups_whole, "{ctx}: groups_whole");
    assert_eq!(a.delegations, b.delegations, "{ctx}: delegations");
}

fn assert_summaries_identical(
    a: &SummaryStats,
    b: &SummaryStats,
    ctx: &str,
    name: &str,
) {
    assert_eq!(a.n, b.n, "{ctx}: {name} count");
    for (x, y, field) in [
        (a.mean, b.mean, "mean"),
        (a.p50, b.p50, "p50"),
        (a.p95, b.p95, "p95"),
        (a.p99, b.p99, "p99"),
        (a.min, b.min, "min"),
        (a.max, b.max, "max"),
    ] {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: {name}.{field} {x} != {y}"
        );
    }
}

fn central_cfg() -> GridConfig {
    let mut cfg = presets::uniform_grid(4, 8);
    cfg.workload.jobs = 60;
    cfg.workload.bulk_size = 20;
    cfg.workload.cpu_sec_median = 120.0;
    cfg.workload.cpu_sec_sigma = 0.5;
    cfg.seed = 31;
    cfg
}

/// Run `cfg` eagerly, then again with `source = "streamed"`, through
/// the same public entry point the CLI uses.
fn eager_vs_streamed(mut cfg: GridConfig, ctx: &str) {
    cfg.workload.source = SourceMode::Eager;
    let (_, eager) = run_simulation(&cfg).unwrap();
    cfg.workload.source = SourceMode::Streamed;
    let (world, streamed) = run_simulation(&cfg).unwrap();
    assert_reports_identical(&eager, &streamed, ctx);
    // The streamed run counted its lazy submissions.
    assert_eq!(world.submitted_jobs(), cfg.workload.jobs, "{ctx}");
}

#[test]
fn central_streamed_matches_eager_bit_for_bit() {
    eager_vs_streamed(central_cfg(), "central");
}

#[test]
fn federated_streamed_matches_eager_bit_for_bit() {
    let mut cfg = central_cfg();
    cfg.workload.jobs = 80;
    cfg.federation.peers = 3;
    cfg.federation.gossip_period_s = 60.0;
    cfg.seed = 33;
    eager_vs_streamed(cfg, "federated");
}

#[test]
fn faulted_streamed_matches_eager_bit_for_bit() {
    // Site2 drops while the refill chain is still pulling submissions
    // and recovers mid-run — streaming must not shift the fault clock.
    let plan = FaultPlan {
        events: vec![
            FaultEvent {
                at: 120.0,
                kind: FaultKind::SiteDown { site: "site2".into() },
            },
            FaultEvent {
                at: 700.0,
                kind: FaultKind::SiteUp { site: "site2".into() },
            },
        ],
    };
    let mut cfg = presets::paper_testbed();
    cfg.workload.jobs = 80;
    cfg.workload.bulk_size = 20;
    cfg.seed = 35;
    cfg.workload.source = SourceMode::Eager;
    let subs = generate_workload(&cfg);
    let (_, eager) = run_simulation_with_faults(&cfg, subs, &plan).unwrap();
    cfg.workload.source = SourceMode::Streamed;
    let (_, streamed) = run_simulation_streamed(&cfg, &plan).unwrap();
    assert_reports_identical(&eager, &streamed, "faulted");
}

#[test]
fn spilled_streamed_report_matches_eager_bit_for_bit() {
    // The full pipeline: lazy source + slot recycling + on-disk shard
    // merge, compared against the eager in-memory report. This is the
    // CLI `--source streamed --spill DIR` route end to end.
    let mut cfg = central_cfg();
    cfg.seed = 37;
    // Spread the bulks far apart (mean gap ≫ drain time) so earlier
    // bulks deliver — and recycle — before later ones arrive; the
    // slab's high-water mark then provably sits below the job total.
    cfg.workload.bulk_size = 5;
    cfg.workload.arrival_rate = 0.002;
    let (_, eager) = run_simulation(&cfg).unwrap();
    cfg.workload.source = SourceMode::Streamed;
    let dir = std::env::temp_dir().join("diana-streamed-equiv-spill");
    cfg.sim.spill_dir = dir.to_string_lossy().into_owned();
    let (world, spilled) = run_simulation(&cfg).unwrap();
    assert_reports_identical(&eager, &spilled, "spilled");
    // Recycling actually happened: the slab's high-water mark stayed
    // below the total submitted.
    assert!(
        world.peak_live_jobs() < world.submitted_jobs(),
        "spill run never recycled (peak live {} of {})",
        world.peak_live_jobs(),
        world.submitted_jobs()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn parallel_spilled_matches_serial_spilled_and_eager() {
    // The sharded-spill matrix row: eager in-memory vs serial spill vs
    // parallel spill at `--sim-threads {2,4}`, all through
    // `run_simulation`. The parallel runs must actually take the PDES
    // (no decline recorded) and every report must be byte-identical —
    // each shard spilled into its own `shard-<p>/` subdirectory and
    // the k-way merge reassembled one global stream.
    let root = std::env::temp_dir().join("diana-streamed-equiv-par-spill");
    std::fs::remove_dir_all(&root).ok();
    let mut cfg = central_cfg();
    cfg.seed = 41;
    cfg.workload.bulk_size = 5;
    cfg.workload.arrival_rate = 0.002;
    let (_, eager) = run_simulation(&cfg).unwrap();
    cfg.workload.source = SourceMode::Streamed;
    let mut serial_cfg = cfg.clone();
    serial_cfg.sim.spill_dir =
        root.join("serial").to_string_lossy().into_owned();
    let (_, serial) = run_simulation(&serial_cfg).unwrap();
    assert_reports_identical(&eager, &serial, "serial spill");
    for threads in [2usize, 4] {
        let ctx = format!("parallel spill t{threads}");
        let mut par_cfg = cfg.clone();
        par_cfg.sim.threads = threads;
        par_cfg.sim.spill_dir = root
            .join(format!("par-t{threads}"))
            .to_string_lossy()
            .into_owned();
        let (world, parallel) = run_simulation(&par_cfg).unwrap();
        assert!(parallel.pdes_parallel, "{ctx}: fell back to serial");
        assert_eq!(parallel.pdes_decline, None, "{ctx}: decline recorded");
        assert_reports_identical(&serial, &parallel, &ctx);
        // Per-shard recycling engaged: peak live sits below the total.
        assert!(
            world.peak_live_jobs() < world.submitted_jobs(),
            "{ctx}: never recycled (peak live {} of {})",
            world.peak_live_jobs(),
            world.submitted_jobs()
        );
        // The spill base really was sharded.
        let shards: Vec<String> =
            std::fs::read_dir(&par_cfg.sim.spill_dir)
                .unwrap()
                .map(|e| e.unwrap().file_name().into_string().unwrap())
                .collect();
        assert!(
            shards.iter().all(|n| n.starts_with("shard-")),
            "{ctx}: unexpected spill layout {shards:?}"
        );
        assert!(!shards.is_empty(), "{ctx}: no shard subdirectories");
    }
    std::fs::remove_dir_all(&root).ok();
}
