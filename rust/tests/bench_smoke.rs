//! Smoke tests mirroring the eight `harness = false` bench binaries
//! (benches/bench_*.rs): each test constructs the same workload the
//! bench constructs (at a reduced scale) and runs one iteration of the
//! benched operation. This guards the bench wiring — the types, builder
//! recipes and entry points the benches depend on — from silently
//! rotting, since `cargo test` does not compile bench targets.
//! (`ci.sh` additionally runs `cargo build --benches` to compile the
//! real binaries.)

use diana::config::{presets, Policy, SchedulerConfig};
use diana::cost::{CostEngine, CostInputs, RustEngine, Weights};
use diana::data::Catalog;
use diana::job::{Job, JobClass, JobId, UserId};
use diana::network::{PingerMonitor, Topology};
use diana::scheduler::{make_picker, GridView, SiteSnapshot};
use diana::sim::EventQueue;
use diana::util::Pcg64;

/// bench_cost_engine: one §V matchmaking round over random inputs.
#[test]
fn cost_engine_workload_constructs_and_runs() {
    let mut rng = Pcg64::new(1);
    let (nj, ns) = (25, 5);
    let mut inp = CostInputs::new(nj, ns);
    for j in 0..nj {
        inp.set_job_row(j, &[
            rng.uniform(0.0, 30_000.0) as f32,
            rng.uniform(0.0, 2_000.0) as f32,
            rng.uniform(1.0, 200.0) as f32,
            rng.uniform(1.0, 7200.0) as f32,
            0.0,
            0.0,
        ]);
    }
    for s in 0..ns {
        inp.set_site_row(s, &[
            rng.below(500) as f32,
            rng.uniform(1.0, 600.0) as f32,
            0.0,
            0.0,
            0.0,
            1.0,
            0.0,
            0.0,
        ]);
    }
    let w = Weights { q_total: 500.0, ..Weights::default() };
    let mut engine = RustEngine::new();
    let out = engine.schedule_step(&inp, &w).unwrap();
    assert_eq!(out.total.len(), nj * ns);
    assert_eq!(out.best_total.len(), nj);
}

/// bench_priority: one §X re-prioritization sweep over a random queue.
#[test]
fn priority_workload_constructs_and_runs() {
    let mut rng = Pcg64::new(2);
    let l = 16usize;
    let mut jobs = Vec::with_capacity(l * 4);
    for _ in 0..l {
        jobs.extend_from_slice(&[
            1.0 + rng.below(50) as f32,
            1.0 + rng.below(32) as f32,
            rng.uniform(100.0, 5000.0) as f32,
            0.0,
        ]);
    }
    let totals = [rng.uniform(50.0, 500.0) as f32,
                  rng.uniform(1000.0, 50_000.0) as f32, l as f32, 0.0];
    let mut engine = RustEngine::new();
    let (pr, qi) = engine.reprioritize(&jobs, &totals).unwrap();
    assert_eq!(pr.len(), l);
    assert_eq!(qi.len(), l);
}

/// bench_scheduler: the per-policy matchmaking fixture + one pick each.
#[test]
fn scheduler_workload_constructs_and_runs() {
    let cfg = presets::uniform_grid(4, 8);
    let topo = Topology::from_config(&cfg);
    let monitor = PingerMonitor::new(&topo, 0.0, 1);
    let mut rng = Pcg64::new(3);
    let mut catalog = Catalog::new();
    for d in 0..10 {
        catalog.add(&format!("d{d}"), rng.uniform(100.0, 30_000.0),
                    vec![rng.below(4) as usize]);
    }
    let sites: Vec<SiteSnapshot> = (0..4)
        .map(|_| SiteSnapshot {
            queue_len: rng.below(20) as usize,
            capability: 8.0,
            load: rng.next_f64(),
            free_slots: rng.below(9) as usize,
            cpus: 8,
            alive: true,
        })
        .collect();
    let jobs: Vec<Job> = (0..32)
        .map(|i| Job {
            id: JobId(i),
            user: UserId((i % 4) as u32),
            group: None,
            class: match i % 3 {
                0 => JobClass::ComputeIntensive,
                1 => JobClass::DataIntensive,
                _ => JobClass::Both,
            },
            input: Some(rng.below(10) as usize),
            in_mb: rng.uniform(10.0, 10_000.0),
            out_mb: 50.0,
            exe_mb: 20.0,
            cpu_sec: rng.uniform(60.0, 3600.0),
            procs: 1 + (i % 4) as usize,
            submit_site: 0,
            submit_time: 0.0,
            quota: 1000.0,
            migrations: 0,
        })
        .collect();
    let view = GridView {
        now: 0.0,
        sites: &sites,
        monitor: &monitor,
        catalog: &catalog,
        q_total: 50,
        epoch: 0,
    };
    for policy in [Policy::Diana, Policy::FcfsBroker, Policy::Greedy,
                   Policy::DataLocal, Policy::Random] {
        let mut picker = make_picker(policy, Box::new(RustEngine::new()),
                                     &SchedulerConfig::default(), 1);
        let picks = picker.pick(&jobs, &view).unwrap();
        assert_eq!(picks.len(), jobs.len(), "{policy:?}");
        assert!(picks.iter().all(|&s| s < 4), "{policy:?}");
    }
}

/// bench_sim: event-heap churn plus a miniature whole-world run.
#[test]
fn sim_workload_constructs_and_runs() {
    let mut q = EventQueue::new();
    for i in 0..500usize {
        q.schedule(i as f64 * 0.5, i);
    }
    let mut popped = 0;
    while q.pop().is_some() {
        popped += 1;
    }
    assert_eq!(popped, 500);

    let mut cfg = presets::paper_testbed();
    cfg.workload.jobs = 20;
    cfg.workload.bulk_size = 10;
    cfg.workload.cpu_sec_median = 30.0;
    let subs = diana::coordinator::generate_workload(&cfg);
    let (w, report) =
        diana::coordinator::run_simulation_with(&cfg, subs).unwrap();
    assert_eq!(report.jobs, 20);
    assert!(w.events_processed() > 20);
}

/// bench_world: the end-to-end shapes (small / flood / federated, plus
/// the streamed-flood bounded-memory shape) build and run once each,
/// and the peak counters the bench reports are live.
#[test]
fn world_bench_workloads_construct_and_run() {
    // Miniature versions of the bench's three shapes.
    let mut small = presets::uniform_grid(4, 4);
    small.workload.jobs = 20;
    small.workload.bulk_size = 10;
    small.workload.cpu_sec_median = 60.0;
    small.seed = 11;
    let mut flood = presets::uniform_grid(8, 16);
    flood.workload.jobs = 50;
    flood.workload.bulk_size = 25;
    flood.workload.arrival_rate = 5.0;
    flood.workload.cpu_sec_median = 120.0;
    flood.seed = 12;
    let mut federated = flood.clone();
    federated.federation.peers = 4;
    federated.federation.gossip_period_s = 60.0;
    federated.seed = 13;
    for (name, cfg) in
        [("small", small), ("flood", flood), ("federated", federated)]
    {
        let subs = diana::coordinator::generate_workload(&cfg);
        let (w, report) =
            diana::coordinator::run_simulation_with(&cfg, subs).unwrap();
        assert_eq!(report.jobs, cfg.workload.jobs, "{name}");
        assert!(w.events_processed() > 0, "{name}");
        assert!(w.peak_live_jobs() > 0, "{name}");
        assert!(w.peak_heap_depth() > 0, "{name}");
    }
    // Miniature streamed-flood: lazy diurnal arrivals + spill/recycle,
    // the same wiring the bench's bounded-memory shape drives.
    let mut streamed = presets::uniform_grid(8, 16);
    streamed.workload.jobs = 60;
    streamed.workload.bulk_size = 25;
    streamed.workload.source = diana::config::SourceMode::Arrival;
    streamed.workload.arrival = diana::config::ArrivalKind::Diurnal;
    streamed.workload.arrival_rate = 0.06;
    streamed.workload.cpu_sec_median = 60.0;
    streamed.seed = 14;
    let spill = std::env::temp_dir().join("diana-bench-smoke-spill");
    streamed.sim.spill_dir = spill.to_string_lossy().into_owned();
    let (w, report) =
        diana::coordinator::run_simulation(&streamed).unwrap();
    assert_eq!(report.jobs, 60, "streamed-flood");
    assert!(w.peak_live_jobs() > 0, "streamed-flood");
    assert_eq!(w.submitted_jobs(), 60, "streamed-flood");
    std::fs::remove_dir_all(&spill).ok();
    // The sharded-spill twins (streamed-flood-t2 / -t4): the same lazy
    // stream through the parallel engine — each shard spilling into its
    // own subdirectory, report k-way merged back.
    for threads in [2usize, 4] {
        let mut cfg = streamed.clone();
        cfg.sim.threads = threads;
        let spill = std::env::temp_dir()
            .join(format!("diana-bench-smoke-spill-t{threads}"));
        cfg.sim.spill_dir = spill.to_string_lossy().into_owned();
        let (w, report) =
            diana::coordinator::run_simulation(&cfg).unwrap();
        assert_eq!(report.jobs, 60, "streamed-flood-t{threads}");
        assert!(report.pdes_parallel, "streamed-flood-t{threads}");
        assert_eq!(w.submitted_jobs(), 60, "streamed-flood-t{threads}");
        std::fs::remove_dir_all(&spill).ok();
    }
}

/// bench_figures: the cheap closed-form figures regenerate.
#[test]
fn figures_workload_constructs_and_runs() {
    for fig in ["fig3", "fig6"] {
        let text = diana::repro::run_figure(fig).unwrap();
        assert!(!text.is_empty(), "{fig} produced no output");
    }
}

/// bench_matchmaker: old-style vs scalar-workspace vs SoA-vectorized
/// round, reduced (J, S), with the same argmin + `to_bits` cross-checks
/// the bench performs.
#[test]
fn matchmaker_workload_constructs_and_runs() {
    use diana::cost::{schedule_step_scalar_into, CostWorkspace};
    use diana::data::ReplicaCache;
    use diana::scheduler::{build_cost_inputs, build_cost_inputs_into};

    let (nj, ns) = (8usize, 6usize);
    let cfg = presets::uniform_grid(ns, 32);
    let topo = Topology::from_config(&cfg);
    let monitor = PingerMonitor::new(&topo, 0.0, 1);
    let mut rng = Pcg64::new(0x5eed);
    let mut catalog = Catalog::new();
    for d in 0..4 {
        catalog.add(&format!("d{d}"), 1000.0,
                    vec![rng.below(ns as u64) as usize]);
    }
    let sites: Vec<SiteSnapshot> = (0..ns)
        .map(|_| SiteSnapshot {
            queue_len: rng.below(50) as usize,
            capability: 32.0,
            load: rng.next_f64(),
            free_slots: rng.below(33) as usize,
            cpus: 32,
            alive: true,
        })
        .collect();
    let jobs: Vec<Job> = (0..nj as u64)
        .map(|i| Job {
            id: JobId(i),
            user: UserId(0),
            group: None,
            class: JobClass::Both,
            input: if i % 4 == 3 { None } else { Some((i % 4) as usize) },
            in_mb: 100.0 * (1 + i) as f64,
            out_mb: 50.0,
            exe_mb: 20.0,
            cpu_sec: 600.0,
            procs: 1,
            submit_site: 0,
            submit_time: 0.0,
            quota: 1000.0,
            migrations: 0,
        })
        .collect();
    let view = GridView {
        now: 0.0,
        sites: &sites,
        monitor: &monitor,
        catalog: &catalog,
        q_total: 50,
        epoch: 0,
    };
    let w = Weights { q_total: 50.0, ..Weights::default() };
    let mut engine = RustEngine::new();
    let inp = build_cost_inputs(&jobs, &view);
    let old = engine.schedule_step(&inp, &w).unwrap();
    let mut ws = CostWorkspace::new();
    let mut replicas = ReplicaCache::new();
    for _ in 0..3 {
        build_cost_inputs_into(&jobs, &view, &mut ws.inputs, &mut replicas);
        engine.schedule_step_into(&ws.inputs, &w, &mut ws.out).unwrap();
    }
    assert_eq!(old.best_total, ws.out.best_total);
    assert_eq!(old.best_compute, ws.out.best_compute);
    assert_eq!(old.best_data, ws.out.best_data);
    assert_eq!(old.total, ws.out.total);
    // Scalar oracle through a reused workspace — the bench's third
    // variant — must be bit-identical to the vectorized round.
    let mut scalar = CostWorkspace::new();
    build_cost_inputs_into(&jobs, &view, &mut scalar.inputs, &mut replicas);
    schedule_step_scalar_into(&scalar.inputs, &w, &mut scalar.out);
    let bits =
        |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&scalar.out.total), bits(&ws.out.total));
    assert_eq!(bits(&scalar.out.net), bits(&ws.out.net));
    assert_eq!(bits(&scalar.out.dtc), bits(&ws.out.dtc));
    assert_eq!(bits(&scalar.out.comp), bits(&ws.out.comp));
    assert_eq!(scalar.out.best_total, ws.out.best_total);
}
