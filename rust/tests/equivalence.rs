//! Workspace/cache ≡ from-scratch equivalence suite.
//!
//! The incremental matchmaking core (reused `CostWorkspace` buffers,
//! event-driven `GridStateCache` rows, epoch-keyed `ReplicaCache`) must
//! be **behavior-preserving**: every placement, event and report column
//! must be byte-identical to the paranoid rebuild path
//! (`GridConfig::paranoid_rebuild`), which reconstructs every scheduling
//! input from scratch each round. (The one deliberate semantic change
//! of the refactor — the migration sweep's batch-frozen Q, see
//! docs/PERFORMANCE.md — applies to both sides of this diff; what the
//! suite proves is that the *caching* never changes behavior.)
//!
//! The check runs a randomized fixture sweep — several topologies ×
//! workloads × seeds, central and federated, faults included — through
//! the real sweep runner and diffs the rendered runs/aggregate CSVs
//! (the same artifacts ci.sh compares between `-j` counts).

use diana::scenario::{run_one, SweepReport, SweepSpec};

/// Run one spec's matrix twice — cached vs paranoid — and assert the
/// serialized reports match byte-for-byte.
fn assert_sweep_equivalence(spec_toml: &str, name: &str) {
    let spec = SweepSpec::from_str_named(spec_toml, name).unwrap();
    let runs = spec.expand().unwrap();
    assert!(!runs.is_empty(), "{name}: empty matrix");
    let mut cached = Vec::with_capacity(runs.len());
    let mut paranoid = Vec::with_capacity(runs.len());
    for run in &runs {
        cached.push(run_one(run, &spec.faults).unwrap());
        let mut p = run.clone();
        p.cfg.paranoid_rebuild = true;
        paranoid.push(run_one(&p, &spec.faults).unwrap());
    }
    let a = SweepReport::build(&spec, cached);
    let b = SweepReport::build(&spec, paranoid);
    assert_eq!(a.runs_csv(), b.runs_csv(), "{name}: runs CSV diverged");
    assert_eq!(a.aggregate_csv(), b.aggregate_csv(),
               "{name}: aggregate CSV diverged");
    assert_eq!(a.to_json(), b.to_json(), "{name}: JSON diverged");
}

#[test]
fn central_matrix_is_equivalent() {
    // Two topologies (uniform grid, heterogeneous paper testbed) ×
    // workload axis × seeds.
    for preset in ["uniform-4x4", "paper-testbed"] {
        assert_sweep_equivalence(
            &format!(
                "name = \"eq-central-{preset}\"\n\
                 preset = \"{preset}\"\n\
                 repeats = 2\n\
                 base_seed = 101\n\
                 [axes]\n\
                 jobs = [40, 80]\n\
                 [set]\n\
                 bulk_size = 10\n\
                 cpu_sec_median = 60.0\n\
                 cpu_sec_sigma = 0.3\n\
                 in_mb_median = 50.0\n"
            ),
            preset,
        );
    }
}

#[test]
fn migration_pressure_is_equivalent() {
    // Bursty one-site submission pattern: congestion, §IX sweeps and
    // batched J×S migration rounds all fire.
    // NOTE: a `seed` axis and `repeats > 1` are mutually exclusive in
    // SweepSpec — the explicit axis supplies the repeats here.
    assert_sweep_equivalence(
        "name = \"eq-migration\"\n\
         preset = \"uniform-4x4\"\n\
         base_seed = 7\n\
         [axes]\n\
         seed = [3, 9]\n\
         [set]\n\
         jobs = 150\n\
         bulk_size = 75\n\
         arrival_rate = 10.0\n\
         cpu_sec_median = 600.0\n\
         max_group_per_site = 100\n\
         congestion_thrs = 0.05\n\
         migration_period_s = 10.0\n",
        "eq-migration",
    );
}

#[test]
fn federated_matrix_is_equivalent() {
    // Peer counts × gossip cadence: delegation views, forwards and
    // partition-scoped migration all exercised.
    assert_sweep_equivalence(
        "name = \"eq-federated\"\n\
         preset = \"uniform-6x4\"\n\
         repeats = 2\n\
         base_seed = 23\n\
         [axes]\n\
         federation.peers = [2, 3]\n\
         [set]\n\
         jobs = 60\n\
         bulk_size = 12\n\
         cpu_sec_median = 120.0\n\
         federation.gossip_period_s = 20.0\n",
        "eq-federated",
    );
}

#[test]
fn faulted_run_is_equivalent() {
    // Faults drive the epoch-invalidation paths: site death (forced
    // migration), link degradation + heal (topology epoch), blackout.
    let spec = SweepSpec::from_str_named(
        "name = \"eq-faults\"\n\
         preset = \"uniform-4x4\"\n\
         base_seed = 5\n\
         [set]\n\
         jobs = 60\n\
         bulk_size = 10\n\
         cpu_sec_median = 60.0\n\
         [[fault]]\n\
         at = 10.0\n\
         kind = \"site-down\"\n\
         site = \"s2\"\n\
         [[fault]]\n\
         at = 40.0\n\
         kind = \"link-degrade\"\n\
         from = \"s0\"\n\
         to = \"s1\"\n\
         rtt_factor = 10.0\n\
         loss_add = 0.05\n\
         capacity_factor = 0.1\n\
         [[fault]]\n\
         at = 300.0\n\
         kind = \"heal\"\n\
         [[fault]]\n\
         at = 500.0\n\
         kind = \"site-up\"\n\
         site = \"s2\"\n",
        "eq-faults",
    )
    .unwrap();
    let runs = spec.expand().unwrap();
    for run in &runs {
        let a = run_one(run, &spec.faults).unwrap();
        let mut p = run.clone();
        p.cfg.paranoid_rebuild = true;
        let b = run_one(&p, &spec.faults).unwrap();
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.queue.mean, b.queue.mean);
        assert_eq!(a.turnaround.p99, b.turnaround.p99);
    }
}
