//! Sweep subsystem properties: the aggregate report is bit-identical
//! for any `-j`, per-run seeds depend only on the matrix position (never
//! on worker scheduling), and the fault-injection scenarios actually
//! exercise §IX failover/migration.

use diana::scenario::{library, run_sweep, SweepSpec};

/// Tier-1 acceptance property: `-j 1` and `-j 8` produce byte-identical
/// CSV and JSON output for the same spec.
#[test]
fn smoke_sweep_j1_equals_j8_bit_for_bit() {
    let spec = library::load("smoke").unwrap();
    let a = run_sweep(&spec, 1).unwrap();
    let b = run_sweep(&spec, 8).unwrap();
    assert_eq!(a.runs_csv(), b.runs_csv());
    assert_eq!(a.aggregate_csv(), b.aggregate_csv());
    assert_eq!(a.to_json(), b.to_json());
}

/// Repeated parallel execution of the same spec is stable (no hidden
/// global state, no wall-clock leakage into the report).
#[test]
fn parallel_sweep_is_reproducible_across_invocations() {
    let spec = library::load("smoke").unwrap();
    let a = run_sweep(&spec, 3).unwrap();
    let b = run_sweep(&spec, 5).unwrap();
    assert_eq!(a.to_json(), b.to_json());
}

/// Seeds are a pure function of the matrix position: `base_seed + index`
/// with repeats innermost — regardless of how workers pick up runs.
#[test]
fn per_run_seeds_follow_matrix_position() {
    let spec = library::load("flash-crowd").unwrap();
    let runs = spec.expand().unwrap();
    assert_eq!(runs.len(), 8); // 2 rates × 2 bulk sizes × 2 repeats
    for (i, r) in runs.iter().enumerate() {
        assert_eq!(r.index, i);
        assert_eq!(r.seed, 100 + i as u64); // flash-crowd base_seed = 100
        assert_eq!(r.cfg.seed, r.seed);
        assert_eq!(r.repeat, i % 2);
    }
    // The parallel runner reports exactly those seeds, in matrix order.
    let rep = run_sweep(&library::load("smoke").unwrap(), 4).unwrap();
    let expanded = library::load("smoke").unwrap().expand().unwrap();
    assert_eq!(rep.runs.len(), expanded.len());
    for (res, spec_run) in rep.runs.iter().zip(&expanded) {
        assert_eq!(res.index, spec_run.index);
        assert_eq!(res.seed, spec_run.seed);
        assert_eq!(res.labels, spec_run.labels);
    }
}

/// Acceptance: the cascading-failure scenario drives §IX forced
/// migration off dead sites — nonzero migrations in the report — and
/// still delivers every job.
#[test]
fn cascading_failure_scenario_migrates_and_completes() {
    let spec = library::load("cascading-failure").unwrap();
    let rep = run_sweep(&spec, 2).unwrap();
    assert!(
        rep.total_migrations() > 0,
        "no migrations despite two site crashes"
    );
    for r in &rep.runs {
        assert_eq!(r.jobs, 150, "run {} lost jobs", r.index);
    }
    // Migrations also surface in the aggregate rows.
    assert!(rep.aggregates.iter().map(|a| a.migrations).sum::<u64>() > 0);
}

/// The emitted CSV/JSON schema matches the checked-in golden files that
/// ci.sh also validates against.
#[test]
fn smoke_sweep_matches_golden_schema() {
    let rep = run_sweep(&library::load("smoke").unwrap(), 2).unwrap();
    let runs_header = rep.runs_csv().lines().next().unwrap().to_string();
    assert_eq!(
        runs_header,
        include_str!("golden/smoke_runs_header.csv").trim_end(),
        "runs CSV header drifted from golden"
    );
    let agg_header =
        rep.aggregate_csv().lines().next().unwrap().to_string();
    assert_eq!(
        agg_header,
        include_str!("golden/smoke_aggregate_header.csv").trim_end(),
        "aggregate CSV header drifted from golden"
    );
    let json = rep.to_json();
    for key in include_str!("golden/smoke_json_keys.txt").lines() {
        assert!(
            json.contains(&format!("\"{key}\"")),
            "JSON lost golden key {key}"
        );
    }
    // 2 job counts × 2 policies, one repeat each.
    assert_eq!(rep.runs.len(), 4);
    assert_eq!(rep.aggregates.len(), 4);
}

/// A custom inline spec exercises file-free parsing and the `[set]` +
/// axes override order (axes win over `[set]`).
#[test]
fn axes_override_set_values() {
    let spec = SweepSpec::from_str_named(
        "preset = \"uniform-2x2\"\n[axes]\njobs = [7]\n[set]\njobs = 99\n",
        "t",
    )
    .unwrap();
    let runs = spec.expand().unwrap();
    assert_eq!(runs.len(), 1);
    assert_eq!(runs[0].cfg.workload.jobs, 7);
}
