//! Property-based tests over the DIANA invariants.
//!
//! The offline crate set has no `proptest`; `prop!` below is a seeded
//! random-case harness (PCG64 per case, failing seed reported) covering
//! the same ground: generate → check invariant → shrink-by-reseed.

use diana::cost::{reprioritize_rust, schedule_step_rust, CostInputs,
                  Weights};
use diana::job::{JobId, JobIdx, UserId};
use diana::migration::{decide, MigrationDecision, PeerReport};
use diana::priority::{self, queue_for_priority};
use diana::queues::{MetaJob, MultilevelQueue};
use diana::sim::EventQueue;
use diana::util::Pcg64;

/// Run `cases` random cases; panics with the failing seed.
fn prop<F: Fn(&mut Pcg64) -> Result<(), String>>(name: &str, cases: u64, f: F) {
    for case in 0..cases {
        let seed = 0xD1A7A ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Pcg64::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed (seed {seed:#x}): {msg}");
        }
    }
}

fn random_inputs(rng: &mut Pcg64) -> (CostInputs, Weights) {
    let nj = 1 + rng.below(64) as usize;
    let ns = 1 + rng.below(16) as usize;
    let mut inp = CostInputs::new(nj, ns);
    for j in 0..nj {
        inp.set_job_row(j, &[
            rng.uniform(0.0, 50_000.0) as f32,
            rng.uniform(0.0, 5_000.0) as f32,
            rng.uniform(0.0, 500.0) as f32,
            rng.uniform(1.0, 7200.0) as f32,
            0.0,
            0.0,
        ]);
    }
    let mut any_alive = false;
    for s in 0..ns {
        // Draw order matches the feature order (alive last) so seeds keep
        // generating the same cases they did pre-SoA.
        let mut row = [0.0f32; 8];
        row[0] = rng.below(1000) as f32;
        row[1] = rng.uniform(0.5, 1000.0) as f32;
        row[2] = rng.next_f64() as f32;
        row[3] = rng.uniform(1.0, 10_000.0) as f32;
        row[4] = rng.uniform(0.0, 0.2) as f32;
        row[5] = if rng.next_f64() < 0.8 { 1.0 } else { 0.0 };
        inp.set_site_row(s, &row);
        any_alive |= row[5] == 1.0;
    }
    if !any_alive {
        inp.site_alive[0] = 1.0;
    }
    for v in inp.link_bw.iter_mut() {
        *v = rng.uniform(0.0, 10_000.0) as f32; // 0 exercises the guard
    }
    for v in inp.link_loss.iter_mut() {
        *v = rng.uniform(0.0, 0.3) as f32;
    }
    let w = Weights {
        w5: rng.uniform(0.1, 4.0) as f32,
        w6: rng.uniform(0.0, 2.0) as f32,
        w7: rng.uniform(0.0, 4.0) as f32,
        q_total: rng.below(5000) as f32,
        w_net: rng.uniform(0.1, 2.0) as f32,
        w_dtc: rng.uniform(0.1, 2.0) as f32,
        ..Weights::default()
    };
    (inp, w)
}

#[test]
fn prop_cost_matrix_finite_and_argmin_consistent() {
    prop("cost finite + argmin", 200, |rng| {
        let (inp, w) = random_inputs(rng);
        let out = schedule_step_rust(&inp, &w);
        for (i, &t) in out.total.iter().enumerate() {
            if !t.is_finite() {
                return Err(format!("total[{i}] = {t}"));
            }
        }
        for j in 0..inp.n_jobs {
            let best = out.best_total[j] as usize;
            for s in 0..inp.n_sites {
                if out.total_at(j, best) > out.total_at(j, s) {
                    return Err(format!(
                        "job {j}: best {best} not minimal vs {s}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dead_sites_never_selected_while_alive_exists() {
    prop("dead site exclusion", 200, |rng| {
        let (inp, w) = random_inputs(rng);
        let alive: Vec<bool> =
            inp.site_alive.iter().map(|&a| a == 1.0).collect();
        if !alive.iter().any(|&a| a) {
            return Ok(());
        }
        let out = schedule_step_rust(&inp, &w);
        for j in 0..inp.n_jobs {
            for (name, arr) in [("total", &out.best_total),
                                ("compute", &out.best_compute),
                                ("data", &out.best_data)] {
                let s = arr[j] as usize;
                if !alive[s] {
                    return Err(format!("job {j}: {name} chose dead {s}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_priority_always_in_unit_interval() {
    prop("Pr ∈ (-1, 1]", 300, |rng| {
        let l = 1 + rng.below(200) as usize;
        let mut jobs = Vec::with_capacity(l * 4);
        for _ in 0..l {
            jobs.extend_from_slice(&[
                1.0 + rng.below(100) as f32,
                1.0 + rng.below(64) as f32,
                rng.uniform(1.0, 10_000.0) as f32,
                0.0,
            ]);
        }
        let totals = [rng.uniform(1.0, 2000.0) as f32,
                      rng.uniform(1.0, 100_000.0) as f32, l as f32, 0.0];
        let (pr, qi) = reprioritize_rust(&jobs, &totals);
        for (i, &p) in pr.iter().enumerate() {
            if !(p > -1.0 - 1e-5 && p <= 1.0 + 1e-5) {
                return Err(format!("pr[{i}] = {p}"));
            }
            if qi[i] != queue_for_priority(p) as i32 {
                return Err(format!("queue mismatch at {i}: {p} → {}", qi[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_priority_monotone_in_user_job_count() {
    prop("Pr decreasing in n", 200, |rng| {
        let t = 1.0 + rng.below(32) as f32;
        let q = rng.uniform(10.0, 5000.0) as f32;
        let cap_t = rng.uniform(1.0, 1000.0) as f32;
        let cap_q = rng.uniform(10.0, 50_000.0) as f32;
        let mut last = f32::INFINITY;
        for n in 1..60 {
            let p = priority::pr(n as f32, q, t, cap_t, cap_q);
            if p >= last {
                return Err(format!("n={n}: {p} !< {last}"));
            }
            last = p;
        }
        Ok(())
    });
}

#[test]
fn prop_multilevel_queue_conserves_jobs() {
    prop("queue conservation", 150, |rng| {
        let mut q = MultilevelQueue::new(0.0);
        let n = 1 + rng.below(100) as usize;
        for i in 0..n {
            q.insert(MetaJob {
                job: JobId(i as u64),
                slot: JobIdx(i as u32),
                user: UserId(rng.below(5) as u32),
                procs: 1 + rng.below(8) as u32,
                quota: rng.uniform(10.0, 5000.0) as f32,
                priority: rng.uniform(-0.999, 1.0) as f32,
                enqueued_at: rng.uniform(0.0, 1000.0),
            });
        }
        if q.len() != n {
            return Err(format!("after insert: {} != {n}", q.len()));
        }
        // A re-prioritization sweep must not create or lose jobs.
        let mut e = diana::cost::RustEngine::new();
        let sweep = priority::sweep(&mut e, &q.all_facts())
            .map_err(|e| e.to_string())?;
        q.apply(&sweep);
        if q.len() != n {
            return Err(format!("after sweep: {} != {n}", q.len()));
        }
        // Drain + reinsert conserves too.
        let drained = q.drain_low_priority(1 + rng.below(10) as usize);
        let d = drained.len();
        for j in drained {
            q.insert(j);
        }
        if q.len() != n {
            return Err(format!("after drain({d})+reinsert: {}", q.len()));
        }
        // Popping everything yields each job exactly once.
        let mut seen = std::collections::BTreeSet::new();
        while let Some(j) = q.pop_best(2000.0) {
            if !seen.insert(j.job.0) {
                return Err(format!("job {:?} popped twice", j.job));
            }
        }
        if seen.len() != n {
            return Err(format!("popped {} of {n}", seen.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_pop_order_respects_queue_levels() {
    prop("pop order", 150, |rng| {
        let mut q = MultilevelQueue::new(0.0);
        let n = 2 + rng.below(60) as usize;
        for i in 0..n {
            q.insert(MetaJob {
                job: JobId(i as u64),
                slot: JobIdx(i as u32),
                user: UserId(0),
                procs: 1,
                quota: 1.0,
                priority: rng.uniform(-0.999, 1.0) as f32,
                enqueued_at: i as f64,
            });
        }
        let mut last_queue = 0usize;
        while let Some(j) = q.pop_best(1e9) {
            let qi = queue_for_priority(j.priority);
            if qi < last_queue {
                return Err(format!(
                    "Q{} popped after Q{}", qi + 1, last_queue + 1
                ));
            }
            last_queue = qi;
        }
        Ok(())
    });
}

#[test]
fn prop_migration_never_cycles_and_never_picks_dead() {
    prop("migration sanity", 200, |rng| {
        let mk = |site: usize, rng: &mut Pcg64| PeerReport {
            site,
            jobs_ahead: rng.below(50) as usize,
            queue_len: rng.below(100) as usize,
            total_cost: rng.uniform(0.0, 100.0) as f32,
            alive: rng.next_f64() < 0.8,
        };
        let mut local = mk(0, rng);
        local.alive = true;
        let peers: Vec<PeerReport> = (1..6).map(|s| mk(s, rng)).collect();
        // Exhausted migration budget → always stay.
        if decide(local, &peers, 1, 1) != MigrationDecision::StayLocal {
            return Err("migrated past budget".into());
        }
        match decide(local, &peers, 1, 0) {
            MigrationDecision::Migrate { to } => {
                let p = peers.iter().find(|p| p.site == to).unwrap();
                if !p.alive {
                    return Err(format!("picked dead peer {to}"));
                }
                if p.jobs_ahead >= local.jobs_ahead {
                    return Err("peer not strictly better".into());
                }
                if p.total_cost > local.total_cost {
                    return Err("peer costs more".into());
                }
            }
            MigrationDecision::StayLocal => {}
        }
        Ok(())
    });
}

#[test]
fn prop_toml_numbers_roundtrip() {
    prop("toml numbers", 100, |rng| {
        let i = rng.next_u64() as i64 / 2;
        let f = rng.uniform(-1e6, 1e6);
        let text = format!("a = {i}\nb = {f}\nc = true\n");
        let t = diana::config::toml::parse(&text).map_err(|e| e.to_string())?;
        if t["a"].as_int() != Some(i) {
            return Err(format!("int {i} mangled"));
        }
        let back = t["b"].as_float().unwrap();
        if (back - f).abs() > 1e-9 * f.abs().max(1.0) {
            return Err(format!("float {f} → {back}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sjf_minimises_mean_wait_among_random_orders() {
    prop("SJF optimality", 100, |rng| {
        use diana::queues::{mean_wait_sequential, sjf_order};
        let n = 2 + rng.below(20) as usize;
        let jobs: Vec<diana::job::Job> = (0..n)
            .map(|i| diana::job::Job {
                id: JobId(i as u64),
                user: UserId(0),
                group: None,
                class: diana::job::JobClass::Both,
                input: None,
                in_mb: 0.0,
                out_mb: 0.0,
                exe_mb: 0.0,
                cpu_sec: rng.uniform(1.0, 1000.0),
                // procs ties to cpu so the proc-based key is aligned:
                procs: 1,
                submit_site: 0,
                submit_time: 0.0,
                quota: 1.0,
                migrations: 0,
            })
            .collect();
        let sjf = sjf_order(&jobs);
        let sjf_wait = mean_wait_sequential(&jobs, &sjf);
        let mut order: Vec<usize> = (0..n).collect();
        for _ in 0..10 {
            rng.shuffle(&mut order);
            if sjf_wait > mean_wait_sequential(&jobs, &order) + 1e-6 {
                return Err("random order beat SJF".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_padding_preserves_results() {
    prop("padding equivalence", 100, |rng| {
        let (inp, w) = random_inputs(rng);
        let direct = schedule_step_rust(&inp, &w);
        let padded = schedule_step_rust(&diana::runtime::pad_inputs(&inp), &w);
        for j in 0..inp.n_jobs {
            if padded.best_total[j] != direct.best_total[j] {
                return Err(format!("job {j} argmin changed by padding"));
            }
        }
        Ok(())
    });
}

/// Reference model for the event queue: the `BinaryHeap`-based
/// implementation the 4-ary indexed heap replaced, kept verbatim (clamp
/// semantics included) as the determinism oracle. The golden sweep CSVs
/// depend on the pop order `(time, seq)` being exactly FIFO for
/// simultaneous events — this is the contract under test.
mod reference_heap {
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    struct Entry<E> {
        time: f64,
        seq: u64,
        event: E,
    }

    impl<E> PartialEq for Entry<E> {
        fn eq(&self, other: &Self) -> bool {
            self.time == other.time && self.seq == other.seq
        }
    }

    impl<E> Eq for Entry<E> {}

    impl<E> Ord for Entry<E> {
        fn cmp(&self, other: &Self) -> Ordering {
            other
                .time
                .total_cmp(&self.time)
                .then_with(|| other.seq.cmp(&self.seq))
        }
    }

    impl<E> PartialOrd for Entry<E> {
        fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
            Some(self.cmp(other))
        }
    }

    pub struct RefQueue<E> {
        heap: BinaryHeap<Entry<E>>,
        now: f64,
        seq: u64,
    }

    impl<E> Default for RefQueue<E> {
        fn default() -> Self {
            Self::new()
        }
    }

    impl<E> RefQueue<E> {
        pub fn new() -> Self {
            RefQueue { heap: BinaryHeap::new(), now: 0.0, seq: 0 }
        }

        pub fn schedule(&mut self, at: f64, event: E) {
            assert!(at.is_finite() && at >= 0.0);
            let t = if at < self.now { self.now } else { at };
            self.heap.push(Entry { time: t, seq: self.seq, event });
            self.seq += 1;
        }

        pub fn pop(&mut self) -> Option<(f64, u64, E)> {
            let e = self.heap.pop()?;
            self.now = e.time;
            Some((e.time, e.seq, e.event))
        }
    }
}

#[test]
fn prop_event_heap_matches_binary_heap_reference() {
    use reference_heap::RefQueue;
    prop("event heap vs BinaryHeap reference", 60, |rng| {
        let mut new_q: EventQueue<u64> = EventQueue::new();
        let mut ref_q: RefQueue<u64> = RefQueue::new();
        let mut tag = 0u64; // payload = schedule order = expected seq
        let ops = 200 + rng.below(800);
        for _ in 0..ops {
            if rng.next_f64() < 0.6 {
                // Coarse time grid (×0.25) forces plenty of exact ties,
                // including past times that exercise the now-clamp.
                let at = rng.below(400) as f64 * 0.25;
                new_q.schedule(at, tag);
                ref_q.schedule(at, tag);
                tag += 1;
            } else {
                let got = new_q.pop();
                let want = ref_q.pop();
                match (got, want) {
                    (None, None) => {}
                    (Some((t, e)), Some((rt, rseq, re))) => {
                        if t != rt || e != re || e != rseq {
                            return Err(format!(
                                "pop diverged: got ({t}, {e}), reference \
                                 ({rt}, seq {rseq}, {re})"
                            ));
                        }
                    }
                    (g, w) => {
                        return Err(format!(
                            "emptiness diverged: {g:?} vs reference {w:?}"
                        ));
                    }
                }
            }
        }
        // Drain both: the tails must agree event-for-event too.
        loop {
            match (new_q.pop(), ref_q.pop()) {
                (None, None) => break,
                (Some((t, e)), Some((rt, rseq, re))) => {
                    if t != rt || e != re || e != rseq {
                        return Err(format!(
                            "drain diverged: got ({t}, {e}), reference \
                             ({rt}, seq {rseq}, {re})"
                        ));
                    }
                }
                _ => return Err("drain emptiness diverged".into()),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dynamic_lookahead_never_exceeds_true_pair_constraint() {
    use diana::federation::Partition;
    use diana::network::Topology;
    use diana::sim::pdes_lookahead_matrix;
    prop("dynamic lookahead soundness", 80, |rng| {
        // Random uniform grid, random contiguous partition, random
        // smallest output size.
        let sites = 4 + rng.below(6) as usize;
        let cfg = diana::config::presets::uniform_grid(sites, 4);
        let pristine = Topology::from_config(&cfg);
        let mut topo = pristine.clone();
        let peers = 2 + rng.below(3) as usize; // 2..=4, sites >= 4
        let part = Partition::contiguous(sites, peers);
        let min_out = rng.uniform(0.5, 200.0);
        // Arbitrary degrade/heal sequence; after every step the matrix
        // must stay sound against the *mutated* topology.
        for _ in 0..(1 + rng.below(12)) {
            if rng.next_f64() < 0.25 {
                topo.restore_links_from(&pristine);
            } else {
                let a = rng.below(sites as u64) as usize;
                let b = rng.below(sites as u64) as usize;
                if a != b {
                    topo.degrade_link(
                        a,
                        b,
                        rng.uniform(0.5, 20.0),
                        rng.uniform(0.0, 0.2),
                        rng.uniform(0.05, 2.0),
                    );
                }
            }
            let central = pdes_lookahead_matrix(&topo, &part, false, min_out);
            let fed = pdes_lookahead_matrix(&topo, &part, true, min_out);
            for (name, m) in [("central", &central), ("federated", &fed)] {
                if m.len() != peers * peers {
                    return Err(format!("{name}: matrix len {}", m.len()));
                }
                for q in 0..peers {
                    if !m[q * peers + q].is_infinite() {
                        return Err(format!(
                            "{name}: diagonal [{q}][{q}] = {} (a shard \
                             never constrains itself)",
                            m[q * peers + q]
                        ));
                    }
                }
            }
            for q in 0..peers {
                for p in 0..peers {
                    if q == p {
                        continue;
                    }
                    // Brute-force oracle over the mutated topology: the
                    // cheapest latency a q→p output delivery can carry.
                    // Every matrix entry must lower-bound it ("never
                    // exceeds the true minimum constraint") — a bound
                    // above it would let a shard drain past an arrival.
                    let mut oracle = f64::INFINITY;
                    for &a in part.sites_of(q) {
                        for &b in part.sites_of(p) {
                            oracle =
                                oracle.min(topo.transfer_seconds(a, b, min_out));
                        }
                    }
                    let c = central[q * peers + p];
                    let f = fed[q * peers + p];
                    if c > oracle {
                        return Err(format!(
                            "central [{q}][{p}] = {c} exceeds oracle {oracle}"
                        ));
                    }
                    if f > oracle {
                        return Err(format!(
                            "federated [{q}][{p}] = {f} exceeds oracle \
                             {oracle}"
                        ));
                    }
                    // Federated adds the forward class: its bound can
                    // only tighten, and the RTT clamp keeps it positive
                    // (the progress guarantee).
                    if f > c {
                        return Err(format!(
                            "federated [{q}][{p}] = {f} looser than \
                             central {c}"
                        ));
                    }
                    if !(f > 0.0) {
                        return Err(format!(
                            "federated [{q}][{p}] = {f} not positive"
                        ));
                    }
                }
            }
        }
        // A heal must restore the pristine matrix bit-for-bit.
        topo.restore_links_from(&pristine);
        let healed = pdes_lookahead_matrix(&topo, &part, true, min_out);
        let original = pdes_lookahead_matrix(&pristine, &part, true, min_out);
        for (i, (a, b)) in original.iter().zip(healed.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("entry {i} not restored: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_barrier_merge_matches_single_queue_reference() {
    use diana::sim::Mailbox;
    prop("barrier merge vs single-queue reference", 400, |rng| {
        // Random cross-peer event batches: per sender, seqs increase
        // and times are nondecreasing (the extraction contract), drawn
        // from a coarse grid so simultaneous timestamps — including
        // cross-sender ties — occur constantly.
        let n_peers = 2 + rng.below(5) as usize;
        let mut msgs: Vec<(f64, usize, u64, u32)> = Vec::new();
        let mut payload = 0u32;
        for peer in 0..n_peers {
            let n = rng.below(12);
            let mut seq = rng.below(4);
            let mut t = 0.0;
            for _ in 0..n {
                t += rng.below(3) as f64 * 0.5; // plateaus => time ties
                msgs.push((t, peer, seq, payload));
                seq += 1 + rng.below(3); // gaps: seqs need not be dense
                payload += 1;
            }
        }
        // Single-queue reference order on (time, sender_peer, seq),
        // built by successive stable sorts (LSD radix) — a different
        // algorithm from the Mailbox comparator.
        let mut oracle = msgs.clone();
        oracle.sort_by_key(|m| m.2);
        oracle.sort_by_key(|m| m.1);
        oracle.sort_by(|a, b| a.0.total_cmp(&b.0));
        // Two different shuffled "extraction orders" (different thread
        // interleavings at a barrier) must both drain in oracle order.
        for round in 0..2u32 {
            let mut shuffled = msgs.clone();
            for i in (1..shuffled.len()).rev() {
                let j = rng.below(i as u64 + 1) as usize;
                shuffled.swap(i, j);
            }
            let mut mb: Mailbox<u32> = Mailbox::new();
            for &(t, p, s, m) in &shuffled {
                mb.push(t, p, s, m);
            }
            let merged: Vec<(f64, usize, u64, u32)> =
                mb.drain_merged().collect();
            if merged != oracle {
                return Err(format!(
                    "round {round}: merge diverged from the single-queue \
                     reference:\n  got  {merged:?}\n  want {oracle:?}"
                ));
            }
            if !mb.is_empty() {
                return Err("mailbox not empty after drain".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spill_merge_matches_single_sorted_oracle() {
    use diana::metrics::{MergedRows, Recorder};
    let root = std::env::temp_dir().join("diana-prop-spill-merge");
    std::fs::remove_dir_all(&root).ok();
    prop("k-way spill merge vs sorted-vector oracle", 50, |rng| {
        // Random shard count; a shard that draws no ordinals stays
        // empty and contributes no files. Tiny random flush buffers
        // force many small files with overlapping ordinal ranges, the
        // case the per-file heap cursors exist for.
        let shards = 1 + rng.below(6) as usize;
        let n = rng.below(120) as usize;
        let dir = root.join("case");
        std::fs::remove_dir_all(&dir).ok();
        let mut recs: Vec<Recorder> = (0..shards)
            .map(|s| {
                let mut r = Recorder::new(1, 10.0);
                r.enable_spill_with_buffer(
                    dir.join(format!("shard-{s}")),
                    1 + rng.below(9) as usize,
                )
                .map_err(|e| e.to_string())?;
                Ok(r)
            })
            .collect::<Result<_, String>>()?;
        // Duplicate-free ordinals 0..n, each sealed on one random
        // shard in random global order; every f64 field carries raw
        // random bits (signed zeros, subnormal magnitudes, either
        // sign) that must round-trip the hex encoding exactly.
        let mut order: Vec<u64> = (0..n as u64).collect();
        for i in (1..order.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            order.swap(i, j);
        }
        let mut oracle: Vec<(u64, [u64; 6], usize, u32)> = Vec::new();
        for &o in &order {
            let draw = |rng: &mut Pcg64| -> f64 {
                match rng.below(8) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => 1e-300 * rng.next_f64(),
                    _ => (rng.next_f64() - 0.5) * 1e9,
                }
            };
            let vals = [
                draw(rng),
                draw(rng),
                draw(rng),
                draw(rng),
                draw(rng),
                draw(rng),
            ];
            let site = rng.below(64) as usize;
            let migs = rng.below(7) as u32;
            let rec = &mut recs[rng.below(shards as u64) as usize];
            let r = rec.job_mut(JobIdx(0));
            r.submit = vals[0];
            r.placed = vals[1];
            r.enqueued_local = vals[2];
            r.started = vals[3];
            r.finished = vals[4];
            r.delivered = vals[5];
            r.exec_site = site;
            r.migrations = migs;
            rec.seal(JobIdx(0), o).map_err(|e| e.to_string())?;
            oracle.push((o, vals.map(f64::to_bits), site, migs));
        }
        oracle.sort_by_key(|e| e.0);
        let mut files = Vec::new();
        for rec in recs.iter_mut() {
            rec.flush_spill_tail().map_err(|e| e.to_string())?;
            files.extend(rec.spill_files());
        }
        let mut rows =
            MergedRows::open(&files).map_err(|e| e.to_string())?;
        let mut got = 0usize;
        while let Some((o, r)) =
            rows.next_row().map_err(|e| e.to_string())?
        {
            let (wo, bits, site, migs) = oracle[got];
            if o != wo {
                return Err(format!("ordinal {o} at rank {got}, want {wo}"));
            }
            let have = [
                r.submit,
                r.placed,
                r.enqueued_local,
                r.started,
                r.finished,
                r.delivered,
            ]
            .map(f64::to_bits);
            if have != bits {
                return Err(format!(
                    "ordinal {o}: bits {have:?} != {bits:?}"
                ));
            }
            if r.exec_site != site || r.migrations != migs {
                return Err(format!("ordinal {o}: int fields diverged"));
            }
            got += 1;
        }
        if got != n {
            return Err(format!("merged {got} rows, sealed {n}"));
        }
        Ok(())
    });
    std::fs::remove_dir_all(&root).ok();
}
