//! Differential property suite: the vectorized §V kernel
//! (`schedule_step_into`) vs the scalar oracle (`schedule_step_rust`),
//! `to_bits`-exact.
//!
//! The vectorized path hoists per-site terms, chunks the J×S sweep into
//! `LANES`-wide spans and runs a separate argmin pass — every one of
//! those restructurings is claimed to be bit-preserving. This suite is
//! the proof: randomized shapes (0/1 jobs, S = 1, non-multiple-of-LANES
//! remainders), dead sites, NaN/∞ link rows and eps-clamped zero
//! bandwidths, all compared bit-for-bit on the four output matrices and
//! all three per-class argmin columns. Any re-association, FMA fusion
//! or reduction reorder that changes even one ULP fails here.

use diana::cost::{
    schedule_step_into, schedule_step_rust, CostInputs, ScheduleOut, Weights,
    LANES,
};
use diana::util::Pcg64;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Compare oracle vs vectorized on one input, bitwise.
fn assert_bit_identical(
    inp: &CostInputs,
    w: &Weights,
    out: &mut ScheduleOut,
    label: &str,
) {
    let oracle = schedule_step_rust(inp, w);
    schedule_step_into(inp, w, out);
    assert_eq!(bits(&out.total), bits(&oracle.total), "{label}: total");
    assert_eq!(bits(&out.net), bits(&oracle.net), "{label}: net");
    assert_eq!(bits(&out.dtc), bits(&oracle.dtc), "{label}: dtc");
    assert_eq!(bits(&out.comp), bits(&oracle.comp), "{label}: comp");
    assert_eq!(out.best_total, oracle.best_total, "{label}: best_total");
    assert_eq!(out.best_compute, oracle.best_compute, "{label}: best_compute");
    assert_eq!(out.best_data, oracle.best_data, "{label}: best_data");
}

/// Random well-formed inputs: finite features, ~20% dead sites, link
/// bandwidth spanning zero (the eps guard) to very fast.
fn random_inputs(rng: &mut Pcg64, nj: usize, ns: usize) -> (CostInputs, Weights) {
    let mut inp = CostInputs::new(nj, ns);
    for j in 0..nj {
        inp.set_job_row(j, &[
            rng.uniform(0.0, 50_000.0) as f32,
            rng.uniform(0.0, 5_000.0) as f32,
            rng.uniform(0.0, 500.0) as f32,
            rng.uniform(1.0, 7200.0) as f32,
            rng.below(3) as f32,
            0.0,
        ]);
    }
    for s in 0..ns {
        inp.set_site_row(s, &[
            rng.below(1000) as f32,
            rng.uniform(0.0, 1000.0) as f32, // 0 exercises the Pi guard
            rng.next_f64() as f32,
            rng.uniform(0.0, 10_000.0) as f32, // 0 exercises client guard
            rng.uniform(0.0, 0.2) as f32,
            if rng.next_f64() < 0.8 { 1.0 } else { 0.0 },
            0.0,
            0.0,
        ]);
    }
    for v in inp.link_bw.iter_mut() {
        // 0 exercises the max(eps) divide-guard.
        *v = if rng.next_f64() < 0.05 {
            0.0
        } else {
            rng.uniform(0.0, 10_000.0) as f32
        };
    }
    for v in inp.link_loss.iter_mut() {
        *v = rng.uniform(0.0, 0.3) as f32;
    }
    let w = Weights {
        w5: rng.uniform(0.1, 4.0) as f32,
        w6: rng.uniform(0.0, 2.0) as f32,
        w7: rng.uniform(0.0, 4.0) as f32,
        q_total: rng.below(5000) as f32,
        w_net: rng.uniform(0.1, 2.0) as f32,
        w_dtc: rng.uniform(0.1, 2.0) as f32,
        ..Weights::default()
    };
    (inp, w)
}

#[test]
fn random_shapes_bit_identical() {
    let mut out = ScheduleOut::default();
    for case in 0..300u64 {
        let seed = 0x51AD ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Pcg64::new(seed);
        let nj = rng.below(80) as usize; // 0 jobs included
        let ns = 1 + rng.below(70) as usize; // S = 1 included
        let (inp, w) = random_inputs(&mut rng, nj, ns);
        assert_bit_identical(&inp, &w, &mut out,
                             &format!("seed {seed:#x} ({nj}x{ns})"));
    }
}

#[test]
fn lane_remainder_shapes_bit_identical() {
    // Every remainder class around the LANES chunk width, plus S = 1 and
    // the exact-multiple shapes where the remainder span is empty.
    let mut out = ScheduleOut::default();
    let mut rng = Pcg64::new(0xC0FFEE);
    for ns in 1..=(3 * LANES + 1) {
        for nj in [0usize, 1, 2, 7] {
            let (inp, w) = random_inputs(&mut rng, nj, ns);
            assert_bit_identical(&inp, &w, &mut out, &format!("({nj}x{ns})"));
        }
    }
}

#[test]
fn nan_and_infinity_link_rows_bit_identical() {
    // NaN / ∞ in the link matrices must propagate identically through
    // both paths (NaN.max(eps) = eps in Rust; 0 · ∞ = NaN; NaN never
    // wins an argmin). One poisoned row per pattern, sites wide enough
    // to cover full lanes and the remainder.
    let mut out = ScheduleOut::default();
    let mut rng = Pcg64::new(0xBADF00D);
    let (nj, ns) = (6usize, 2 * LANES + 3);
    for pattern in 0..6 {
        let (mut inp, w) = random_inputs(&mut rng, nj, ns);
        match pattern {
            0 => inp.link_bw[ns..2 * ns].fill(f32::NAN),
            1 => inp.link_loss[ns..2 * ns].fill(f32::NAN),
            2 => inp.link_bw[0..ns].fill(f32::INFINITY),
            3 => inp.link_loss[2 * ns..3 * ns].fill(f32::INFINITY),
            4 => {
                // in_mb = 0 against bw = ∞: 0/∞ = 0, then 0 · (1+loss).
                inp.job_in_mb[3] = 0.0;
                inp.link_bw[3 * ns..4 * ns].fill(f32::INFINITY);
            }
            _ => {
                // Whole-row NaN: every key NaN → argmin stays at 0.
                inp.link_bw[4 * ns..5 * ns].fill(f32::NAN);
                inp.link_loss[4 * ns..5 * ns].fill(f32::NAN);
            }
        }
        assert_bit_identical(&inp, &w, &mut out, &format!("pattern {pattern}"));
    }
}

#[test]
fn all_nan_row_leaves_argmin_at_zero() {
    // Both paths must agree on the degenerate all-NaN row — and the
    // agreed answer is index 0 (strict `<` never accepts NaN).
    let mut inp = CostInputs::new(1, LANES + 2);
    for s in 0..inp.n_sites {
        inp.set_site_row(s, &[1.0, 8.0, 0.5, 100.0, 0.01, 1.0, 0.0, 0.0]);
    }
    inp.set_job_row(0, &[100.0, 10.0, 5.0, 60.0, 2.0, 0.0]);
    inp.link_loss.fill(f32::NAN);
    let w = Weights::default();
    let oracle = schedule_step_rust(&inp, &w);
    let mut out = ScheduleOut::default();
    schedule_step_into(&inp, &w, &mut out);
    assert!(out.total.iter().all(|t| t.is_nan()));
    assert_eq!(out.best_total, vec![0]);
    assert_eq!(out.best_total, oracle.best_total);
    assert_eq!(bits(&out.total), bits(&oracle.total));
}

#[test]
fn dead_site_masking_bit_identical_and_masked() {
    // Kill every site except one; both paths must produce the same bits
    // and both argmins must land on the lone alive site.
    let mut rng = Pcg64::new(0xDEAD);
    let (nj, ns) = (5usize, 3 * LANES - 1);
    let (mut inp, w) = random_inputs(&mut rng, nj, ns);
    let alive = (rng.below(ns as u64)) as usize;
    for s in 0..ns {
        inp.site_alive[s] = if s == alive { 1.0 } else { 0.0 };
    }
    let mut out = ScheduleOut::default();
    assert_bit_identical(&inp, &w, &mut out, "dead mask");
    for j in 0..nj {
        assert_eq!(out.best_total[j] as usize, alive);
        assert_eq!(out.best_compute[j] as usize, alive);
        assert_eq!(out.best_data[j] as usize, alive);
    }
}

#[test]
fn argmin_tie_break_picks_lowest_site_index() {
    // Identical sites + identical links ⇒ every cost column is constant
    // per job; the strict-`<` scan must keep index 0 on both paths.
    let (nj, ns) = (3usize, 2 * LANES + 5);
    let mut inp = CostInputs::new(nj, ns);
    for s in 0..ns {
        inp.set_site_row(s, &[5.0, 16.0, 0.25, 500.0, 0.02, 1.0, 0.0, 0.0]);
    }
    for j in 0..nj {
        inp.set_job_row(j, &[1000.0, 20.0, 5.0, 600.0, 1.0, 0.0]);
    }
    inp.link_bw.fill(250.0);
    inp.link_loss.fill(0.05);
    let w = Weights { q_total: 40.0, ..Weights::default() };
    let mut out = ScheduleOut::default();
    assert_bit_identical(&inp, &w, &mut out, "tie break");
    assert_eq!(out.best_total, vec![0; nj]);
    assert_eq!(out.best_compute, vec![0; nj]);
    assert_eq!(out.best_data, vec![0; nj]);
}

#[test]
fn eps_clamped_zero_bandwidth_bit_identical() {
    // All-zero bandwidths everywhere: every divide runs on the eps
    // guard. Costs are huge but finite, and identical across paths.
    let (nj, ns) = (4usize, LANES + 1);
    let mut inp = CostInputs::new(nj, ns);
    for s in 0..ns {
        inp.set_site_row(s, &[2.0, 8.0, 0.5, 0.0, 0.1, 1.0, 0.0, 0.0]);
    }
    for j in 0..nj {
        inp.set_job_row(j, &[10.0, 5.0, 1.0, 60.0, 0.0, 0.0]);
    }
    inp.link_bw.fill(0.0);
    inp.link_loss.fill(0.2);
    let w = Weights { q_total: 8.0, ..Weights::default() };
    let mut out = ScheduleOut::default();
    assert_bit_identical(&inp, &w, &mut out, "eps clamp");
    assert!(out.total.iter().all(|t| t.is_finite()));
}

#[test]
fn shrink_regrow_reuse_is_bit_identical_and_capacity_stable() {
    // PR 4 capacity-stability discipline extended to the vectorized
    // kernel: one ScheduleOut reused across shrinking/regrowing rounds
    // must stay bit-identical to fresh evaluation and never reallocate
    // once warmed at the largest shape.
    let mut out = ScheduleOut::default();
    let mut rng = Pcg64::new(0x5EED5);
    let (max_j, max_s) = (48usize, 3 * LANES + 2);
    let (warm, w) = random_inputs(&mut rng, max_j, max_s);
    schedule_step_into(&warm, &w, &mut out);
    let caps = out.capacities();
    for (nj, ns) in
        [(1usize, 1usize), (max_j, max_s), (3, LANES), (17, max_s), (0, 5)]
    {
        let (inp, w) = random_inputs(&mut rng, nj, ns);
        assert_bit_identical(&inp, &w, &mut out, &format!("reuse ({nj}x{ns})"));
    }
    assert_eq!(out.capacities(), caps,
               "reused ScheduleOut must not reallocate after warmup");
}
