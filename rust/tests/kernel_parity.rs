//! Rust↔Pallas golden parity: replay the committed goldens under
//! `tests/golden/kernels/` — dumped from the JAX `ref.py` contract by
//! `python/tests/dump_goldens.py` — through `RustEngine`.
//!
//! Floats travel as f32 bit patterns (8 hex digits), so this suite needs
//! no JAX, no Python and no parsing tolerance: the inputs the Rust
//! kernel sees are bit-for-bit the inputs the JAX oracle saw.
//!
//! Comparison gates (the cross-language contract):
//!  * float outputs (total/comp/dtc/net, pr): 1e-5 relative with a 1e-3
//!    absolute floor — XLA may fuse multiply-adds where rustc does not,
//!    so cross-language bit-equality is not promised (the bitwise
//!    promise is Rust-scalar vs Rust-vectorized; see
//!    kernel_differential.rs).
//!  * argmin / queue indices: exact. The dump tool asserts a margin
//!    between best and runner-up so this can never flake under
//!    FMA-level drift.

use std::collections::HashMap;
use std::path::PathBuf;

use diana::cost::{CostEngine, CostInputs, RustEngine, Weights};

const REL_TOL: f64 = 1e-5;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
        .join("kernels")
}

struct Golden {
    fields: HashMap<String, Vec<String>>,
}

impl Golden {
    fn load(path: &std::path::Path) -> Golden {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
        let mut fields = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_whitespace().map(str::to_string);
            let key = toks.next().expect("key");
            fields.insert(key, toks.collect());
        }
        Golden { fields }
    }

    fn usize(&self, key: &str) -> usize {
        self.fields[key][0].parse().unwrap_or_else(|e| {
            panic!("field `{key}`: {e}")
        })
    }

    fn f32s(&self, key: &str) -> Vec<f32> {
        self.fields
            .get(key)
            .unwrap_or_else(|| panic!("missing field `{key}`"))
            .iter()
            .map(|t| {
                let bits = u32::from_str_radix(t, 16)
                    .unwrap_or_else(|e| panic!("field `{key}` token {t}: {e}"));
                f32::from_bits(bits)
            })
            .collect()
    }

    fn i32s(&self, key: &str) -> Vec<i32> {
        self.fields[key]
            .iter()
            .map(|t| t.parse().unwrap())
            .collect()
    }
}

fn assert_rel_close(got: &[f32], want: &[f32], what: &str, name: &str) {
    assert_eq!(got.len(), want.len(), "{name}/{what}: length");
    for (i, (&a, &b)) in got.iter().zip(want).enumerate() {
        let (a, b) = (a as f64, b as f64);
        let rel = (a - b).abs() / b.abs().max(1e-3);
        assert!(
            rel < REL_TOL,
            "{name}/{what}[{i}]: rust {a} vs golden {b} (rel {rel:.2e})"
        );
    }
}

fn replay(path: &std::path::Path) {
    let name = path.file_stem().unwrap().to_string_lossy().into_owned();
    let g = Golden::load(path);
    let (nj, ns) = (g.usize("nj"), g.usize("ns"));

    let mut inp = CostInputs::new(nj, ns);
    inp.job_in_mb = g.f32s("job_in_mb");
    inp.job_out_mb = g.f32s("job_out_mb");
    inp.job_exe_mb = g.f32s("job_exe_mb");
    inp.job_cpu_sec = g.f32s("job_cpu_sec");
    inp.job_class = g.f32s("job_class");
    inp.site_queue = g.f32s("site_queue");
    inp.site_cap = g.f32s("site_cap");
    inp.site_load = g.f32s("site_load");
    inp.site_client_bw = g.f32s("site_client_bw");
    inp.site_client_loss = g.f32s("site_client_loss");
    inp.site_alive = g.f32s("site_alive");
    inp.link_bw = g.f32s("link_bw");
    inp.link_loss = g.f32s("link_loss");
    for (col, len, what) in [
        (inp.job_in_mb.len(), nj, "job_in_mb"),
        (inp.site_queue.len(), ns, "site_queue"),
        (inp.link_bw.len(), nj * ns, "link_bw"),
        (inp.link_loss.len(), nj * ns, "link_loss"),
    ] {
        assert_eq!(col, len, "{name}: {what} length");
    }

    let wv = g.f32s("weights");
    assert_eq!(wv.len(), 8, "{name}: weights length");
    let w = Weights {
        w5: wv[0],
        w6: wv[1],
        w7: wv[2],
        q_total: wv[3],
        w_net: wv[4],
        w_dtc: wv[5],
        eps: wv[6],
        big: wv[7],
    };
    w.validate().unwrap_or_else(|e| panic!("{name}: {e}"));

    let mut engine = RustEngine::new();
    let out = engine.schedule_step(&inp, &w).unwrap();

    assert_rel_close(&out.total, &g.f32s("total"), "total", &name);
    assert_rel_close(&out.comp, &g.f32s("comp"), "comp", &name);
    assert_rel_close(&out.dtc, &g.f32s("dtc"), "dtc", &name);
    assert_rel_close(&out.net, &g.f32s("net"), "net", &name);
    assert_eq!(out.best_total, g.i32s("best_total"), "{name}: best_total");

    // §X priority parity through the same engine.
    let l = g.usize("pr_l");
    let pj = g.f32s("pr_jobs");
    assert_eq!(pj.len(), l * 4, "{name}: pr_jobs length");
    let pt = g.f32s("pr_totals");
    let totals = [pt[0], pt[1], pt[2], pt[3]];
    let (pr, queue) = engine.reprioritize(&pj, &totals).unwrap();
    assert_rel_close(&pr, &g.f32s("pr"), "pr", &name);
    assert_eq!(queue, g.i32s("pr_queue"), "{name}: pr_queue");
}

#[test]
fn all_committed_goldens_replay_within_tolerance() {
    let dir = golden_dir();
    let mut paths: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("reading {}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "golden"))
        .collect();
    paths.sort();
    assert!(
        paths.len() >= 6,
        "expected ≥ 6 committed goldens in {}, found {} — run \
         python3 python/tests/dump_goldens.py",
        dir.display(),
        paths.len()
    );
    for p in &paths {
        replay(p);
    }
}

#[test]
fn golden_fixture_set_is_the_expected_one() {
    // The dump tool's fixture list and this suite must not drift apart:
    // a renamed or dropped fixture should fail loudly, not shrink
    // coverage silently.
    let dir = golden_dir();
    for name in [
        "paper_testbed",
        "uniform_64x8",
        "dead_sites",
        "extreme_bw_loss",
        "single_site",
        "big_256x32",
    ] {
        assert!(
            dir.join(format!("{name}.golden")).exists(),
            "missing golden `{name}` — run python3 python/tests/dump_goldens.py"
        );
    }
}
