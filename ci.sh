#!/usr/bin/env bash
# Tier-1 gate + doc/bench guards. Run from anywhere; operates on the
# workspace at this script's directory.
set -euo pipefail
cd "$(dirname "$0")"

SWEEP_OUT=$(mktemp -d)
trap 'rm -rf "$SWEEP_OUT"' EXIT

echo "== tier-1: build =="
cargo build --release

echo "== bench wiring (harness = false targets compile) =="
cargo build --release --benches

echo "== tier-1: tests =="
cargo test -q

echo "== kernel gate: differential (bitwise) + golden parity, release =="
# Both suites already ran in the debug `cargo test -q` above; the release
# rerun is the one that matters for the SoA kernel — the scalar-vs-
# vectorized bit-identity claim must hold under -O autovectorization,
# not just in the unoptimized build.
cargo test --release -q --test kernel_differential --test kernel_parity

echo "== kernel goldens: regenerate from ref.py + byte-diff (needs JAX) =="
# The committed goldens are the cross-language contract; when a Python
# toolchain with JAX is available, re-derive them from ref.py into a
# scratch dir and byte-compare, so contract drift fails CI instead of
# silently rewriting the committed files.
if python3 -c "import jax" >/dev/null 2>&1; then
  python3 python/tests/dump_goldens.py --out "$SWEEP_OUT/goldens"
  for f in rust/tests/golden/kernels/*.golden; do
    cmp "$f" "$SWEEP_OUT/goldens/$(basename "$f")" \
      || { echo "ci.sh: $(basename "$f") drifted from the ref.py contract \
— rerun python3 python/tests/dump_goldens.py and commit"; exit 1; }
  done
else
  echo "ci.sh: python3/JAX unavailable — replaying committed goldens only"
fi

echo "== clippy (best effort) =="
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "ci.sh: clippy not installed in this toolchain — skipping"
fi

echo "== docs (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== smoke sweep (thread-count determinism + golden schema) =="
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 1 \
    --out "$SWEEP_OUT/j1"
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 2 \
    --out "$SWEEP_OUT/j2"
for f in smoke_runs.csv smoke_aggregate.csv smoke.json; do
  cmp "$SWEEP_OUT/j1/$f" "$SWEEP_OUT/j2/$f" \
    || { echo "ci.sh: $f differs between -j 1 and -j 2"; exit 1; }
done
head -n 1 "$SWEEP_OUT/j1/smoke_runs.csv" \
  | diff - rust/tests/golden/smoke_runs_header.csv
head -n 1 "$SWEEP_OUT/j1/smoke_aggregate.csv" \
  | diff - rust/tests/golden/smoke_aggregate_header.csv
while read -r key; do
  grep -q "\"$key\"" "$SWEEP_OUT/j1/smoke.json" \
    || { echo "ci.sh: smoke.json lost key $key"; exit 1; }
done < rust/tests/golden/smoke_json_keys.txt

echo "== federation smoke sweep (peers 1+2, -j determinism + golden) =="
./target/release/diana sweep rust/examples/sweeps/federation_smoke.toml \
    -j 1 --out "$SWEEP_OUT/fed-j1"
./target/release/diana sweep rust/examples/sweeps/federation_smoke.toml \
    -j 2 --out "$SWEEP_OUT/fed-j2"
for f in federation-smoke_runs.csv federation-smoke_aggregate.csv \
         federation-smoke.json; do
  cmp "$SWEEP_OUT/fed-j1/$f" "$SWEEP_OUT/fed-j2/$f" \
    || { echo "ci.sh: $f differs between -j 1 and -j 2"; exit 1; }
done
head -n 1 "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" \
  | diff - rust/tests/golden/federation_smoke_runs_header.csv
# Full-content golden: record on the first run (commit the file), then
# byte-compare every run after — any drift in the federated schedule,
# gossip cadence or report format fails CI loudly.
FED_GOLDEN=rust/tests/golden/federation_smoke_runs.csv
if [ -f "$FED_GOLDEN" ]; then
  cmp "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" "$FED_GOLDEN" \
    || { echo "ci.sh: federation smoke output drifted from $FED_GOLDEN"; exit 1; }
else
  cp "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" "$FED_GOLDEN"
  echo "ci.sh: bootstrapped $FED_GOLDEN — commit it"
fi

echo "== incremental matchmaking == from-scratch rebuild (bit-for-bit) =="
# The workspace/cache hot path must produce byte-identical sweep output
# to the paranoid rebuild-everything path (same discipline as the
# peers=1 ≡ central check; the in-crate equivalence suite covers more
# matrices, this guards the shipped scenarios end-to-end).
DIANA_PARANOID_REBUILD=1 ./target/release/diana sweep \
    rust/examples/sweeps/smoke.toml -j 1 --out "$SWEEP_OUT/paranoid"
DIANA_PARANOID_REBUILD=1 ./target/release/diana sweep \
    rust/examples/sweeps/federation_smoke.toml -j 1 \
    --out "$SWEEP_OUT/fed-paranoid"
for f in smoke_runs.csv smoke_aggregate.csv; do
  cmp "$SWEEP_OUT/j1/$f" "$SWEEP_OUT/paranoid/$f" \
    || { echo "ci.sh: $f diverged under DIANA_PARANOID_REBUILD"; exit 1; }
done
for f in federation-smoke_runs.csv federation-smoke_aggregate.csv; do
  cmp "$SWEEP_OUT/fed-j1/$f" "$SWEEP_OUT/fed-paranoid/$f" \
    || { echo "ci.sh: $f diverged under DIANA_PARANOID_REBUILD"; exit 1; }
done

echo "== matchmaker bench (smoke) + BENCH_matchmaker.json trajectory =="
# Runs the old-vs-scalar-vs-SoA comparison (incl. the per-shape argmin
# and to_bits cross-checks baked into the bench binary).
cargo bench --bench bench_matchmaker -- --smoke \
    --json "$SWEEP_OUT/BENCH_matchmaker.json" | tee "$SWEEP_OUT/bench.txt"
grep -q "matchmaker events/s" "$SWEEP_OUT/bench.txt" \
  || { echo "ci.sh: matchmaker bench lost its events/s line"; exit 1; }
grep -q '"shapes"' "$SWEEP_OUT/BENCH_matchmaker.json" \
  || { echo "ci.sh: BENCH_matchmaker.json malformed"; exit 1; }
# Soft regression gate, same policy as BENCH_world.json: warn (never
# fail — smoke numbers are noisy) when a shape's rounds/s drops more
# than 15% below the committed trajectory point.
if [ -f BENCH_matchmaker.json ]; then
  for shape in J1xS10 J32xS50 J256xS200 J1024xS500; do
    for col in scalar_rounds_per_s soa_rounds_per_s; do
      old=$(grep -o "\"name\": \"$shape\"[^}]*" BENCH_matchmaker.json \
              | grep -o "\"$col\": [0-9.]*" | grep -o '[0-9.]*$' || true)
      new=$(grep -o "\"name\": \"$shape\"[^}]*" \
              "$SWEEP_OUT/BENCH_matchmaker.json" \
              | grep -o "\"$col\": [0-9.]*" | grep -o '[0-9.]*$' || true)
      if [ -n "$old" ] && [ -n "$new" ]; then
        awk -v o="$old" -v n="$new" -v s="$shape/$col" 'BEGIN {
          if (o > 0 && n < 0.85 * o)
            printf "ci.sh: ⚠ rounds/s regression on %s: %.1f -> %.1f (-%.0f%%)\n",
                   s, o, n, (1 - n / o) * 100
        }'
      fi
    done
  done
else
  echo "ci.sh: no committed BENCH_matchmaker.json yet — bootstrapping"
fi
cp "$SWEEP_OUT/BENCH_matchmaker.json" BENCH_matchmaker.json
echo "ci.sh: BENCH_matchmaker.json refreshed — commit it to record the trajectory point"

echo "== world bench (smoke) + BENCH_world.json perf trajectory =="
cargo bench --bench bench_world -- --smoke \
    --json "$SWEEP_OUT/BENCH_world.json" | tee "$SWEEP_OUT/bench_world.txt"
grep -q "world events/s" "$SWEEP_OUT/bench_world.txt" \
  || { echo "ci.sh: world bench lost its events/s line"; exit 1; }
grep -q '"shapes"' "$SWEEP_OUT/BENCH_world.json" \
  || { echo "ci.sh: BENCH_world.json malformed"; exit 1; }
# Soft regression gate against the committed trajectory point: warn
# (never fail — smoke numbers are noisy) when a shape's events/s drops
# more than 15% below the recorded value.
if [ -f BENCH_world.json ]; then
  for shape in small flood federated federated-t2 federated-t4 \
               central-t2 central-t4 faulted-fed-t4 streamed-flood \
               streamed-flood-t2 streamed-flood-t4; do
    old=$(grep -o "\"name\": \"$shape\", \"events_per_s\": [0-9.]*" \
            BENCH_world.json | grep -o '[0-9.]*$' || true)
    new=$(grep -o "\"name\": \"$shape\", \"events_per_s\": [0-9.]*" \
            "$SWEEP_OUT/BENCH_world.json" | grep -o '[0-9.]*$' || true)
    if [ -n "$old" ] && [ -n "$new" ]; then
      awk -v o="$old" -v n="$new" -v s="$shape" 'BEGIN {
        if (o > 0 && n < 0.85 * o)
          printf "ci.sh: ⚠ events/s regression on %s: %.0f -> %.0f (-%.0f%%)\n",
                 s, o, n, (1 - n / o) * 100
      }'
    fi
  done
else
  echo "ci.sh: no committed BENCH_world.json yet — bootstrapping"
fi
cp "$SWEEP_OUT/BENCH_world.json" BENCH_world.json
echo "ci.sh: BENCH_world.json refreshed — commit it to record the trajectory point"

echo "== PDES smoke (--sim-threads 1 == 4, CLI, bit-for-bit) =="
# The conservative parallel engine must be behavior-preserving: the
# sharded run's full metrics table (every row, incl. the DES event
# count) must byte-match the serial reference. The in-crate
# pdes_equivalence suite sweeps whole matrices; this guards the shipped
# binary end-to-end, and bench_world --smoke above aborts if the
# federated shape ever silently declines the parallel path.
./target/release/diana run --preset uniform --jobs 80 --seed 7 \
    --federation 4 --sim-threads 1 > "$SWEEP_OUT/pdes-t1.txt"
./target/release/diana run --preset uniform --jobs 80 --seed 7 \
    --federation 4 --sim-threads 4 > "$SWEEP_OUT/pdes-t4.txt"
cmp "$SWEEP_OUT/pdes-t1.txt" "$SWEEP_OUT/pdes-t4.txt" \
  || { echo "ci.sh: --sim-threads 4 diverged from --sim-threads 1"; exit 1; }

echo "== central PDES smoke (--sim-threads 1 == 4, no federation) =="
# Plain-central runs are inside the envelope too: sites shard by
# contiguous block and the single scheduler's placement rounds replay
# at window barriers, so a non-federated run must byte-match serial.
./target/release/diana run --preset uniform --jobs 80 --seed 7 \
    --sim-threads 1 > "$SWEEP_OUT/central-pdes-t1.txt"
./target/release/diana run --preset uniform --jobs 80 --seed 7 \
    --sim-threads 4 > "$SWEEP_OUT/central-pdes-t4.txt"
cmp "$SWEEP_OUT/central-pdes-t1.txt" "$SWEEP_OUT/central-pdes-t4.txt" \
  || { echo "ci.sh: central --sim-threads 4 diverged from serial"; exit 1; }

echo "== faulted federated smoke (site down/up, sim.threads 1 == 4) =="
# Site-lifecycle faults are replicated events inside the PDES envelope:
# a sweep that kills s2 mid-run (stranding queued work for the §IX
# force-migration sweep) and revives it later must render byte-identical
# CSV/JSON whether the sim is serial or sharded on 4 threads.
for t in 1 4; do
  cat > "$SWEEP_OUT/faulted-fed-t$t.toml" <<EOF
name = "faulted-fed"
preset = "uniform-6x4"
base_seed = 9
[set]
jobs = 60
bulk_size = 12
cpu_sec_median = 90.0
federation.peers = 2
sim.threads = $t
[[fault]]
at = 30.0
kind = "site-down"
site = "s2"
[[fault]]
at = 300.0
kind = "site-up"
site = "s2"
EOF
  ./target/release/diana sweep "$SWEEP_OUT/faulted-fed-t$t.toml" -j 1 \
      --out "$SWEEP_OUT/faulted-t$t"
done
for f in faulted-fed_runs.csv faulted-fed_aggregate.csv faulted-fed.json; do
  cmp "$SWEEP_OUT/faulted-t1/$f" "$SWEEP_OUT/faulted-t4/$f" \
    || { echo "ci.sh: $f differs between sim.threads 1 and 4"; exit 1; }
done

echo "== federation 1-peer == central (CLI, bit-for-bit) =="
./target/release/diana run --preset uniform --jobs 40 --seed 11 \
    > "$SWEEP_OUT/central.txt"
./target/release/diana run --preset uniform --jobs 40 --seed 11 \
    --federation 1 > "$SWEEP_OUT/fed1.txt"
# Only the mode banner line may differ; every metric row must match.
if ! diff <(tail -n +2 "$SWEEP_OUT/central.txt") \
          <(tail -n +2 "$SWEEP_OUT/fed1.txt"); then
  echo "ci.sh: --federation 1 diverged from the central run"; exit 1
fi

echo "== streamed source == eager (CLI, bit-for-bit) =="
# The streamed route replays the same generator lazily through the
# SourceRefill chain; every metric row must byte-match the eager run.
# Only the banner (which names the source) and the streamed run's
# trailing peak-live line may differ.
./target/release/diana run --preset uniform --jobs 60 --seed 21 \
    > "$SWEEP_OUT/eager.txt"
./target/release/diana run --preset uniform --jobs 60 --seed 21 \
    --source streamed > "$SWEEP_OUT/streamed.txt"
if ! diff <(tail -n +2 "$SWEEP_OUT/eager.txt") \
          <(tail -n +2 "$SWEEP_OUT/streamed.txt" \
            | grep -v '^peak live jobs'); then
  echo "ci.sh: --source streamed diverged from the eager run"; exit 1
fi

echo "== streamed+spilled PDES smoke (100k jobs, sim-threads 1 == 4) =="
# The sharded-spill path end to end on the shipped binary: a 100k-job
# diurnal stream with spill + slot recycling must take the parallel
# engine at --sim-threads 4 (each shard sealing into its own shard-<p>/
# subdirectory, report k-way merged back), stay under the same hard RSS
# ceiling as the serial spill run, and render a byte-identical metrics
# table. Only the peak-RSS line (process noise) and the peak-live line
# (the parallel count is a barrier-sampled upper bound, see
# docs/PERFORMANCE.md) are excluded from the comparison.
for t in 1 4; do
  ./target/release/diana run --preset uniform --sites 16 --cpus 64 \
      --jobs 100000 --bulk 25 --arrival diurnal --rate-mult 0.01 \
      --seed 43 --sim-threads $t --spill "$SWEEP_OUT/spill-t$t" \
      --max-rss-mb 256 > "$SWEEP_OUT/streamed-spill-t$t.txt"
done
if ! diff <(grep -Ev '^(peak RSS|peak live jobs)' \
              "$SWEEP_OUT/streamed-spill-t1.txt") \
          <(grep -Ev '^(peak RSS|peak live jobs)' \
              "$SWEEP_OUT/streamed-spill-t4.txt"); then
  echo "ci.sh: spilled --sim-threads 4 diverged from the serial spill run"
  exit 1
fi
grep -Eq "jobs completed.*100000" "$SWEEP_OUT/streamed-spill-t4.txt" \
  || { echo "ci.sh: streamed+spilled PDES smoke dropped jobs"; exit 1; }
grep -q "peak live jobs" "$SWEEP_OUT/streamed-spill-t4.txt" \
  || { echo "ci.sh: streamed+spilled PDES smoke lost its peak-live line"; exit 1; }
test -d "$SWEEP_OUT/spill-t4/shard-0" \
  || { echo "ci.sh: parallel spill run left no shard-0/ subdirectory"; exit 1; }

echo "== streamed 1M-job run (bounded memory, hard RSS ceiling) =="
# One million diurnal-arrival jobs pulled lazily with spill + slot
# recycling: peak RSS must track *live* jobs (a few hundred at this
# utilization), not the job total — an eager 1M-job run materializes
# the submission list, the slab and the recorder (hundreds of MB).
# --max-rss-mb makes the binary itself assert VmHWM afterwards, so any
# regression back to O(total) memory fails CI loudly.
./target/release/diana run --preset uniform --sites 16 --cpus 64 \
    --jobs 1000000 --bulk 25 --arrival diurnal --rate-mult 0.01 \
    --seed 42 --spill "$SWEEP_OUT/spill" --max-rss-mb 256 \
    > "$SWEEP_OUT/streamed-1m.txt"
grep -Eq "jobs completed.*1000000" "$SWEEP_OUT/streamed-1m.txt" \
  || { echo "ci.sh: streamed 1M-job run dropped jobs"; exit 1; }
grep -q "peak RSS" "$SWEEP_OUT/streamed-1m.txt" \
  || { echo "ci.sh: streamed 1M-job run lost its peak-RSS line"; exit 1; }

echo "== trace reader 1M-line parse smoke (release, ignored test) =="
cargo test --release -q --lib million_line_trace_parse_smoke -- --ignored

echo "ci.sh: all green"
