#!/usr/bin/env bash
# Tier-1 gate + doc/bench guards. Run from anywhere; operates on the
# workspace at this script's directory.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== bench wiring (harness = false targets compile) =="
cargo build --release --benches

echo "== tier-1: tests =="
cargo test -q

echo "== docs (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "ci.sh: all green"
