#!/usr/bin/env bash
# Tier-1 gate + doc/bench guards. Run from anywhere; operates on the
# workspace at this script's directory.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== bench wiring (harness = false targets compile) =="
cargo build --release --benches

echo "== tier-1: tests =="
cargo test -q

echo "== clippy (best effort) =="
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "ci.sh: clippy not installed in this toolchain — skipping"
fi

echo "== docs (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== smoke sweep (thread-count determinism + golden schema) =="
SWEEP_OUT=$(mktemp -d)
trap 'rm -rf "$SWEEP_OUT"' EXIT
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 1 \
    --out "$SWEEP_OUT/j1"
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 2 \
    --out "$SWEEP_OUT/j2"
for f in smoke_runs.csv smoke_aggregate.csv smoke.json; do
  cmp "$SWEEP_OUT/j1/$f" "$SWEEP_OUT/j2/$f" \
    || { echo "ci.sh: $f differs between -j 1 and -j 2"; exit 1; }
done
head -n 1 "$SWEEP_OUT/j1/smoke_runs.csv" \
  | diff - rust/tests/golden/smoke_runs_header.csv
head -n 1 "$SWEEP_OUT/j1/smoke_aggregate.csv" \
  | diff - rust/tests/golden/smoke_aggregate_header.csv
while read -r key; do
  grep -q "\"$key\"" "$SWEEP_OUT/j1/smoke.json" \
    || { echo "ci.sh: smoke.json lost key $key"; exit 1; }
done < rust/tests/golden/smoke_json_keys.txt

echo "== federation smoke sweep (peers 1+2, -j determinism + golden) =="
./target/release/diana sweep rust/examples/sweeps/federation_smoke.toml \
    -j 1 --out "$SWEEP_OUT/fed-j1"
./target/release/diana sweep rust/examples/sweeps/federation_smoke.toml \
    -j 2 --out "$SWEEP_OUT/fed-j2"
for f in federation-smoke_runs.csv federation-smoke_aggregate.csv \
         federation-smoke.json; do
  cmp "$SWEEP_OUT/fed-j1/$f" "$SWEEP_OUT/fed-j2/$f" \
    || { echo "ci.sh: $f differs between -j 1 and -j 2"; exit 1; }
done
head -n 1 "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" \
  | diff - rust/tests/golden/federation_smoke_runs_header.csv
# Full-content golden: record on the first run (commit the file), then
# byte-compare every run after — any drift in the federated schedule,
# gossip cadence or report format fails CI loudly.
FED_GOLDEN=rust/tests/golden/federation_smoke_runs.csv
if [ -f "$FED_GOLDEN" ]; then
  cmp "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" "$FED_GOLDEN" \
    || { echo "ci.sh: federation smoke output drifted from $FED_GOLDEN"; exit 1; }
else
  cp "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" "$FED_GOLDEN"
  echo "ci.sh: bootstrapped $FED_GOLDEN — commit it"
fi

echo "== incremental matchmaking == from-scratch rebuild (bit-for-bit) =="
# The workspace/cache hot path must produce byte-identical sweep output
# to the paranoid rebuild-everything path (same discipline as the
# peers=1 ≡ central check; the in-crate equivalence suite covers more
# matrices, this guards the shipped scenarios end-to-end).
DIANA_PARANOID_REBUILD=1 ./target/release/diana sweep \
    rust/examples/sweeps/smoke.toml -j 1 --out "$SWEEP_OUT/paranoid"
DIANA_PARANOID_REBUILD=1 ./target/release/diana sweep \
    rust/examples/sweeps/federation_smoke.toml -j 1 \
    --out "$SWEEP_OUT/fed-paranoid"
for f in smoke_runs.csv smoke_aggregate.csv; do
  cmp "$SWEEP_OUT/j1/$f" "$SWEEP_OUT/paranoid/$f" \
    || { echo "ci.sh: $f diverged under DIANA_PARANOID_REBUILD"; exit 1; }
done
for f in federation-smoke_runs.csv federation-smoke_aggregate.csv; do
  cmp "$SWEEP_OUT/fed-j1/$f" "$SWEEP_OUT/fed-paranoid/$f" \
    || { echo "ci.sh: $f diverged under DIANA_PARANOID_REBUILD"; exit 1; }
done

echo "== matchmaker bench (smoke) =="
cargo bench --bench bench_matchmaker -- --smoke | tee "$SWEEP_OUT/bench.txt"
grep -q "matchmaker events/s" "$SWEEP_OUT/bench.txt" \
  || { echo "ci.sh: matchmaker bench lost its events/s line"; exit 1; }

echo "== federation 1-peer == central (CLI, bit-for-bit) =="
./target/release/diana run --preset uniform --jobs 40 --seed 11 \
    > "$SWEEP_OUT/central.txt"
./target/release/diana run --preset uniform --jobs 40 --seed 11 \
    --federation 1 > "$SWEEP_OUT/fed1.txt"
# Only the mode banner line may differ; every metric row must match.
if ! diff <(tail -n +2 "$SWEEP_OUT/central.txt") \
          <(tail -n +2 "$SWEEP_OUT/fed1.txt"); then
  echo "ci.sh: --federation 1 diverged from the central run"; exit 1
fi

echo "ci.sh: all green"
