#!/usr/bin/env bash
# Tier-1 gate + doc/bench guards. Run from anywhere; operates on the
# workspace at this script's directory.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== bench wiring (harness = false targets compile) =="
cargo build --release --benches

echo "== tier-1: tests =="
cargo test -q

echo "== docs (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== smoke sweep (thread-count determinism + golden schema) =="
SWEEP_OUT=$(mktemp -d)
trap 'rm -rf "$SWEEP_OUT"' EXIT
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 1 \
    --out "$SWEEP_OUT/j1"
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 2 \
    --out "$SWEEP_OUT/j2"
for f in smoke_runs.csv smoke_aggregate.csv smoke.json; do
  cmp "$SWEEP_OUT/j1/$f" "$SWEEP_OUT/j2/$f" \
    || { echo "ci.sh: $f differs between -j 1 and -j 2"; exit 1; }
done
head -n 1 "$SWEEP_OUT/j1/smoke_runs.csv" \
  | diff - rust/tests/golden/smoke_runs_header.csv
head -n 1 "$SWEEP_OUT/j1/smoke_aggregate.csv" \
  | diff - rust/tests/golden/smoke_aggregate_header.csv
while read -r key; do
  grep -q "\"$key\"" "$SWEEP_OUT/j1/smoke.json" \
    || { echo "ci.sh: smoke.json lost key $key"; exit 1; }
done < rust/tests/golden/smoke_json_keys.txt

echo "== federation smoke sweep (peers 1+2, -j determinism + golden) =="
./target/release/diana sweep rust/examples/sweeps/federation_smoke.toml \
    -j 1 --out "$SWEEP_OUT/fed-j1"
./target/release/diana sweep rust/examples/sweeps/federation_smoke.toml \
    -j 2 --out "$SWEEP_OUT/fed-j2"
for f in federation-smoke_runs.csv federation-smoke_aggregate.csv \
         federation-smoke.json; do
  cmp "$SWEEP_OUT/fed-j1/$f" "$SWEEP_OUT/fed-j2/$f" \
    || { echo "ci.sh: $f differs between -j 1 and -j 2"; exit 1; }
done
head -n 1 "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" \
  | diff - rust/tests/golden/federation_smoke_runs_header.csv
# Full-content golden: record on the first run (commit the file), then
# byte-compare every run after — any drift in the federated schedule,
# gossip cadence or report format fails CI loudly.
FED_GOLDEN=rust/tests/golden/federation_smoke_runs.csv
if [ -f "$FED_GOLDEN" ]; then
  cmp "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" "$FED_GOLDEN" \
    || { echo "ci.sh: federation smoke output drifted from $FED_GOLDEN"; exit 1; }
else
  cp "$SWEEP_OUT/fed-j1/federation-smoke_runs.csv" "$FED_GOLDEN"
  echo "ci.sh: bootstrapped $FED_GOLDEN — commit it"
fi

echo "== federation 1-peer == central (CLI, bit-for-bit) =="
./target/release/diana run --preset uniform --jobs 40 --seed 11 \
    > "$SWEEP_OUT/central.txt"
./target/release/diana run --preset uniform --jobs 40 --seed 11 \
    --federation 1 > "$SWEEP_OUT/fed1.txt"
# Only the mode banner line may differ; every metric row must match.
if ! diff <(tail -n +2 "$SWEEP_OUT/central.txt") \
          <(tail -n +2 "$SWEEP_OUT/fed1.txt"); then
  echo "ci.sh: --federation 1 diverged from the central run"; exit 1
fi

echo "ci.sh: all green"
