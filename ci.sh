#!/usr/bin/env bash
# Tier-1 gate + doc/bench guards. Run from anywhere; operates on the
# workspace at this script's directory.
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: build =="
cargo build --release

echo "== bench wiring (harness = false targets compile) =="
cargo build --release --benches

echo "== tier-1: tests =="
cargo test -q

echo "== docs (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== smoke sweep (thread-count determinism + golden schema) =="
SWEEP_OUT=$(mktemp -d)
trap 'rm -rf "$SWEEP_OUT"' EXIT
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 1 \
    --out "$SWEEP_OUT/j1"
./target/release/diana sweep rust/examples/sweeps/smoke.toml -j 2 \
    --out "$SWEEP_OUT/j2"
for f in smoke_runs.csv smoke_aggregate.csv smoke.json; do
  cmp "$SWEEP_OUT/j1/$f" "$SWEEP_OUT/j2/$f" \
    || { echo "ci.sh: $f differs between -j 1 and -j 2"; exit 1; }
done
head -n 1 "$SWEEP_OUT/j1/smoke_runs.csv" \
  | diff - rust/tests/golden/smoke_runs_header.csv
head -n 1 "$SWEEP_OUT/j1/smoke_aggregate.csv" \
  | diff - rust/tests/golden/smoke_aggregate_header.csv
while read -r key; do
  grep -q "\"$key\"" "$SWEEP_OUT/j1/smoke.json" \
    || { echo "ci.sh: smoke.json lost key $key"; exit 1; }
done < rust/tests/golden/smoke_json_keys.txt

echo "ci.sh: all green"
